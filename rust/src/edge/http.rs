//! Minimal HTTP/1.1 server on `std::net` — no crates, no async runtime.
//!
//! One blocking accept thread feeds a bounded connection queue drained by
//! a small worker pool (`edge_threads`); each worker serves its
//! connection with keep-alive, `Content-Length` framing, and per-read
//! timeouts. The queue is the same [`Bounded`] MPMC channel the
//! coordinator uses, so saturation backpressure propagates to the TCP
//! accept backlog instead of spawning unbounded threads.
//!
//! Scope is deliberately narrow — exactly what the `/v1` routes need:
//! no chunked transfer encoding (411 when a body has no length), no TLS,
//! no HTTP/2. Anything malformed is answered with a 4xx and the
//! connection closed; handler panics are caught and turned into 500s so
//! one bad request can never take a worker thread down.

use crate::util::threadpool::Bounded;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Tuning for [`HttpServer::bind`]; [`HttpOptions::default`] matches the
/// `ServerConfig` defaults.
#[derive(Clone, Debug)]
pub struct HttpOptions {
    /// Worker threads (concurrent connections being served).
    pub threads: usize,
    /// Per-read socket timeout; also bounds how long an idle keep-alive
    /// connection is held open.
    pub read_timeout: Duration,
    /// Largest accepted request body (413 beyond).
    pub max_body_bytes: usize,
    /// Largest accepted request head — request line + headers (431).
    pub max_head_bytes: usize,
    /// Requests served per connection before the server closes it.
    pub keep_alive_max: usize,
}

impl Default for HttpOptions {
    fn default() -> Self {
        Self {
            threads: 4,
            read_timeout: Duration::from_secs(5),
            max_body_bytes: 8 << 20,
            max_head_bytes: 16 << 10,
            keep_alive_max: 1024,
        }
    }
}

/// A parsed request as handed to the route handler.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    /// Full request target (path + optional query).
    pub target: String,
    /// Header names lowercased at parse time.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Target with any `?query` stripped.
    pub fn path(&self) -> &str {
        self.target.split('?').next().unwrap_or(&self.target)
    }

    /// First header with this (case-insensitive) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }
}

#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    /// Extra headers (`Content-Length`/`Connection` are added by the
    /// server when writing).
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Self {
        Self {
            status,
            headers: vec![("Content-Type".into(), "application/json".into())],
            body: body.into_bytes(),
        }
    }

    pub fn text(status: u16, body: &str) -> Self {
        Self {
            status,
            headers: vec![("Content-Type".into(), "text/plain".into())],
            body: body.as_bytes().to_vec(),
        }
    }

    pub fn with_header(mut self, name: &str, value: &str) -> Self {
        self.headers.push((name.into(), value.into()));
        self
    }
}

pub(crate) fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        411 => "Length Required",
        413 => "Content Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "",
    }
}

/// Route handler: pure request → response (shared across workers).
pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

pub struct HttpServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    conns: Bounded<TcpStream>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// the accept thread + worker pool.
    pub fn bind(addr: &str, opts: HttpOptions, handler: Handler) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        // Small queue: excess connections wait in the TCP accept backlog,
        // which is the backpressure we want under connection floods.
        let conns: Bounded<TcpStream> = Bounded::new(opts.threads.max(1) * 2);

        let accept_thread = {
            let conns = conns.clone();
            let shutdown = Arc::clone(&shutdown);
            std::thread::Builder::new()
                .name("edge-accept".into())
                .spawn(move || loop {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if shutdown.load(Ordering::SeqCst) {
                                return; // wake-up connection from shutdown()
                            }
                            if conns.send(stream).is_err() {
                                return;
                            }
                        }
                        Err(_) => {
                            if shutdown.load(Ordering::SeqCst) {
                                return;
                            }
                            // Transient accept error (EMFILE, aborted
                            // handshake): brief pause, keep accepting.
                            std::thread::sleep(Duration::from_millis(10));
                        }
                    }
                })?
        };

        let workers = (0..opts.threads.max(1))
            .map(|i| {
                let conns = conns.clone();
                let opts = opts.clone();
                let handler = Arc::clone(&handler);
                std::thread::Builder::new()
                    .name(format!("edge-worker-{i}"))
                    .spawn(move || {
                        while let Some(stream) = conns.recv() {
                            // A hung peer only ever costs this worker its
                            // read timeout; errors just drop the stream.
                            let _ = serve_connection(stream, &opts, &handler);
                        }
                    })
            })
            .collect::<std::io::Result<Vec<_>>>()?;

        Ok(HttpServer {
            addr: local,
            shutdown,
            accept_thread: Some(accept_thread),
            workers,
            conns,
        })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain workers, join all threads.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        // Unblock the accept() call with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        self.conns.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Head parse outcome: a request, or the status to answer before closing.
enum HeadError {
    /// Peer closed (or idle keep-alive timed out) before a first byte —
    /// close silently.
    Closed,
    /// Malformed/oversized head: answer this status, then close.
    Reply(u16, &'static str),
}

/// Serve one connection until close/keep-alive limit/error.
fn serve_connection(
    mut stream: TcpStream,
    opts: &HttpOptions,
    handler: &Handler,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(opts.read_timeout))?;
    stream.set_nodelay(true).ok();
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    for _ in 0..opts.keep_alive_max {
        let req = match read_request(&mut stream, &mut buf, opts) {
            Ok(req) => req,
            Err(HeadError::Closed) => return Ok(()),
            Err(HeadError::Reply(status, msg)) => {
                write_response(&mut stream, &Response::text(status, msg), false)?;
                return Ok(());
            }
        };
        let keep_alive = req
            .header("connection")
            .map(|v| !v.eq_ignore_ascii_case("close"))
            .unwrap_or(true);
        // One bad request must not take the worker thread down.
        let resp = catch_unwind(AssertUnwindSafe(|| handler(&req)))
            .unwrap_or_else(|_| Response::text(500, "handler panicked"));
        write_response(&mut stream, &resp, keep_alive)?;
        if !keep_alive {
            return Ok(());
        }
    }
    Ok(())
}

/// Read one request (head + body) from the stream. `buf` carries bytes
/// read past the previous request's end (pipelining/keep-alive).
fn read_request(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    opts: &HttpOptions,
) -> Result<Request, HeadError> {
    // Accumulate until the blank line ending the head.
    let head_end = loop {
        if let Some(pos) = find_head_end(buf) {
            break pos;
        }
        if buf.len() > opts.max_head_bytes {
            return Err(HeadError::Reply(431, "request head too large"));
        }
        let mut chunk = [0u8; 4096];
        match stream.read(&mut chunk) {
            Ok(0) => return Err(HeadError::Closed),
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if buf.is_empty() {
                    return Err(HeadError::Closed); // idle keep-alive
                }
                return Err(HeadError::Reply(408, "timed out reading request"));
            }
            Err(_) => return Err(HeadError::Closed),
        }
    };

    let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v)) if !m.is_empty() && t.starts_with('/') => {
            (m.to_string(), t.to_string(), v)
        }
        _ => return Err(HeadError::Reply(400, "malformed request line")),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(HeadError::Reply(400, "unsupported HTTP version"));
    }
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        match line.split_once(':') {
            Some((k, v)) => headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string())),
            None => return Err(HeadError::Reply(400, "malformed header line")),
        }
    }
    let req_head = Request {
        method,
        target,
        headers,
        body: Vec::new(),
    };

    // Body framing: Content-Length only (no chunked support).
    if req_head
        .header("transfer-encoding")
        .is_some_and(|v| !v.eq_ignore_ascii_case("identity"))
    {
        return Err(HeadError::Reply(411, "chunked bodies not supported"));
    }
    let content_length = match req_head.header("content-length") {
        Some(v) => match v.parse::<usize>() {
            Ok(n) => n,
            Err(_) => return Err(HeadError::Reply(400, "bad content-length")),
        },
        None if req_head.method == "POST" || req_head.method == "PUT" => {
            return Err(HeadError::Reply(411, "content-length required"));
        }
        None => 0,
    };
    if content_length > opts.max_body_bytes {
        return Err(HeadError::Reply(413, "request body too large"));
    }

    // The client may be waiting for permission before sending the body.
    if req_head
        .header("expect")
        .is_some_and(|v| v.eq_ignore_ascii_case("100-continue"))
        && stream.write_all(b"HTTP/1.1 100 Continue\r\n\r\n").is_err()
    {
        return Err(HeadError::Closed);
    }

    // Consume the head; read the remainder of the body.
    let body_start = head_end + 4;
    let mut body: Vec<u8> = buf[body_start.min(buf.len())..].to_vec();
    buf.clear();
    while body.len() < content_length {
        let mut chunk = [0u8; 8192];
        match stream.read(&mut chunk) {
            Ok(0) => return Err(HeadError::Closed),
            Ok(n) => body.extend_from_slice(&chunk[..n]),
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                return Err(HeadError::Reply(408, "timed out reading body"));
            }
            Err(_) => return Err(HeadError::Closed),
        }
    }
    // Bytes past the body belong to the next pipelined request.
    if body.len() > content_length {
        buf.extend_from_slice(&body[content_length..]);
        body.truncate(content_length);
    }
    let mut req = req_head;
    req.body = body;
    Ok(req)
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn write_response(
    stream: &mut TcpStream,
    resp: &Response,
    keep_alive: bool,
) -> std::io::Result<()> {
    let mut head = format!("HTTP/1.1 {} {}\r\n", resp.status, reason(resp.status));
    for (k, v) in &resp.headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str(&format!("Content-Length: {}\r\n", resp.body.len()));
    head.push_str(if keep_alive {
        "Connection: keep-alive\r\n\r\n"
    } else {
        "Connection: close\r\n\r\n"
    });
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()
}

/// Tiny blocking HTTP/1.1 client over one keep-alive connection —
/// `Content-Length` framing only, matching the server. Used by the
/// loopback tests and the `edge_load` generator; handy for ops debugging
/// too. (The `edge_client` example deliberately does *not* use it: it
/// hand-writes its bytes to prove the wire format from outside the
/// crate.)
pub struct MiniClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl MiniClient {
    pub fn connect(addr: SocketAddr, timeout: Duration) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(timeout))?;
        stream.set_nodelay(true).ok();
        Ok(Self {
            stream,
            buf: Vec::new(),
        })
    }

    /// Send one request and read the full response → `(status, body)`.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> std::io::Result<(u16, String)> {
        let (status, _head, body) = self.request_with_head(method, path, body)?;
        Ok((status, body))
    }

    /// Like [`Self::request`], but also returns the raw response head
    /// (status line + headers) so tests can pin header contracts such as
    /// `Retry-After` on shed/unhealthy responses.
    pub fn request_with_head(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> std::io::Result<(u16, String, String)> {
        let mut req = format!("{method} {path} HTTP/1.1\r\nHost: edge\r\n");
        if let Some(b) = body {
            req.push_str(&format!(
                "Content-Type: application/json\r\nContent-Length: {}\r\n",
                b.len()
            ));
        }
        req.push_str("\r\n");
        if let Some(b) = body {
            req.push_str(b);
        }
        self.stream.write_all(req.as_bytes())?;

        let head_end = loop {
            if let Some(p) = find_head_end(&self.buf) {
                break p;
            }
            let mut chunk = [0u8; 4096];
            let n = self.stream.read(&mut chunk)?;
            if n == 0 {
                return Err(ErrorKind::UnexpectedEof.into());
            }
            self.buf.extend_from_slice(&chunk[..n]);
        };
        let head = String::from_utf8_lossy(&self.buf[..head_end]).into_owned();
        let status: u16 = head
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| std::io::Error::new(ErrorKind::InvalidData, "bad status line"))?;
        let content_length: usize = head
            .lines()
            .find_map(|l| {
                let (k, v) = l.split_once(':')?;
                if k.trim().eq_ignore_ascii_case("content-length") {
                    v.trim().parse().ok()
                } else {
                    None
                }
            })
            .unwrap_or(0);
        let mut rest = self.buf[head_end + 4..].to_vec();
        self.buf.clear();
        while rest.len() < content_length {
            let mut chunk = [0u8; 8192];
            let n = self.stream.read(&mut chunk)?;
            if n == 0 {
                return Err(ErrorKind::UnexpectedEof.into());
            }
            rest.extend_from_slice(&chunk[..n]);
        }
        if rest.len() > content_length {
            self.buf = rest[content_length..].to_vec();
            rest.truncate(content_length);
        }
        Ok((status, head, String::from_utf8_lossy(&rest).into_owned()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> HttpServer {
        let handler: Handler = Arc::new(|req: &Request| {
            Response::text(200, &format!("{} {} {}", req.method, req.path(), req.body.len()))
        });
        HttpServer::bind("127.0.0.1:0", HttpOptions::default(), handler).unwrap()
    }

    fn roundtrip(addr: SocketAddr, raw: &[u8]) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw).unwrap();
        let mut out = Vec::new();
        s.read_to_end(&mut out).unwrap();
        String::from_utf8_lossy(&out).into_owned()
    }

    #[test]
    fn serves_and_keeps_alive() {
        let srv = echo_server();
        let addr = srv.local_addr();
        // Two requests on one connection; second closes.
        let raw = b"GET /a HTTP/1.1\r\nHost: x\r\n\r\n\
                    POST /b?q=1 HTTP/1.1\r\nHost: x\r\nContent-Length: 3\r\nConnection: close\r\n\r\nxyz";
        let out = roundtrip(addr, raw);
        assert!(out.contains("GET /a 0"), "{out}");
        assert!(out.contains("POST /b 3"), "{out}");
        assert!(out.matches("HTTP/1.1 200 OK").count() == 2, "{out}");
        srv.shutdown();
    }

    #[test]
    fn mini_client_round_trips_keep_alive() {
        let srv = echo_server();
        let mut c = MiniClient::connect(srv.local_addr(), Duration::from_secs(5)).unwrap();
        let (status, body) = c.request("GET", "/one", None).unwrap();
        assert_eq!((status, body.as_str()), (200, "GET /one 0"));
        let (status, body) = c.request("POST", "/two", Some("abcd")).unwrap();
        assert_eq!((status, body.as_str()), (200, "POST /two 4"));
        srv.shutdown();
    }

    #[test]
    fn rejects_malformed_and_oversized() {
        let opts = HttpOptions {
            max_body_bytes: 16,
            ..HttpOptions::default()
        };
        let handler: Handler = Arc::new(|_req: &Request| Response::text(200, "ok"));
        let srv = HttpServer::bind("127.0.0.1:0", opts, handler).unwrap();
        let addr = srv.local_addr();
        let out = roundtrip(addr, b"BOGUS\r\n\r\n");
        assert!(out.starts_with("HTTP/1.1 400"), "{out}");
        let out = roundtrip(addr, b"POST /x HTTP/1.1\r\nContent-Length: 999\r\n\r\n");
        assert!(out.starts_with("HTTP/1.1 413"), "{out}");
        let out = roundtrip(addr, b"POST /x HTTP/1.1\r\nHost: a\r\n\r\n");
        assert!(out.starts_with("HTTP/1.1 411"), "{out}");
        srv.shutdown();
    }

    #[test]
    fn handler_panic_becomes_500() {
        let handler: Handler = Arc::new(|req: &Request| {
            if req.path() == "/boom" {
                panic!("kaboom");
            }
            Response::text(200, "fine")
        });
        let srv = HttpServer::bind("127.0.0.1:0", HttpOptions::default(), handler).unwrap();
        let addr = srv.local_addr();
        let out = roundtrip(addr, b"GET /boom HTTP/1.1\r\nConnection: close\r\n\r\n");
        assert!(out.starts_with("HTTP/1.1 500"), "{out}");
        // The worker survived: a fresh request still works.
        let out = roundtrip(addr, b"GET /ok HTTP/1.1\r\nConnection: close\r\n\r\n");
        assert!(out.starts_with("HTTP/1.1 200"), "{out}");
        srv.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let srv = echo_server();
        let addr = srv.local_addr();
        srv.shutdown();
        // Bind again on the same port to prove the listener is gone.
        let _srv2 = HttpServer::bind(
            &addr.to_string(),
            HttpOptions::default(),
            Arc::new(|_: &Request| Response::text(200, "x")),
        )
        .expect("port should be released after shutdown");
    }
}
