//! L4 network edge: the v1 client API served over the wire.
//!
//! Everything here is `std`-only — `TcpListener`, hand-rolled HTTP/1.1,
//! hand-rolled JSON — because the build environment has no crates.io (see
//! DESIGN.md §8 for the wire format and the shedding state machine). The
//! subsystem splits the same way the serving stack below it does:
//!
//! - [`http`] — transport: accept/worker thread pool, keep-alive,
//!   `Content-Length` framing, timeouts. Knows nothing about inference.
//! - [`json`] — wire codec: lossless encoder (floats round-trip
//!   bit-identically) and a lazy partial-field request scanner.
//! - [`admission`] — pure shed/degrade/escalate policy over the
//!   coordinator's queue-load signal.
//! - [`routes`] — `/v1/*` handlers binding the three together onto
//!   [`Coordinator`](crate::client::Coordinator).
//!
//! ```no_run
//! use bnn_cim::client::{Backend, Config, Coordinator};
//! use bnn_cim::edge::EdgeServer;
//! use std::sync::Arc;
//!
//! let cfg = Config::default();
//! let coord = Arc::new(
//!     Coordinator::builder(cfg).backend(Backend::Sim).start().unwrap(),
//! );
//! let edge = EdgeServer::bind("127.0.0.1:0", coord).unwrap();
//! println!("listening on http://{}", edge.local_addr());
//! edge.shutdown();
//! ```

pub mod admission;
pub mod http;
pub mod json;
pub mod routes;

pub use admission::{AdmissionPolicy, Decision};
pub use http::{Handler, HttpOptions, HttpServer, MiniClient, Request, Response};
pub use json::{scan_infer_batch, Disposition, WireInfer};
pub use routes::{status_for, Router};

use crate::client::{Coordinator, ServeError};
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::Duration;

/// The network edge: an [`HttpServer`] wired to a [`Router`] over a
/// running [`Coordinator`]. Dropping (or [`EdgeServer::shutdown`]) stops
/// the listener and joins the HTTP workers; the coordinator itself is
/// owned via `Arc` and shuts down when the last handle drops.
pub struct EdgeServer {
    http: HttpServer,
}

impl EdgeServer {
    /// Bind `listen` (`host:port`; port 0 picks an ephemeral port) and
    /// serve the coordinator's `/v1` API. HTTP tuning comes from the
    /// coordinator's own `[server]` config (`edge_threads`,
    /// `edge_max_body_bytes`, `request_timeout_ms`).
    pub fn bind(listen: &str, coord: Arc<Coordinator>) -> Result<EdgeServer, ServeError> {
        let cfg = coord.config();
        let opts = HttpOptions {
            threads: cfg.server.edge_threads,
            // Socket reads get the same deadline as blocking waits; the
            // floor keeps pathological configs from busy-looping reads.
            read_timeout: Duration::from_secs_f64(
                (cfg.server.request_timeout_ms / 1e3).max(0.05),
            ),
            max_body_bytes: cfg.server.edge_max_body_bytes,
            ..HttpOptions::default()
        };
        let router = Arc::new(Router::new(coord));
        let handler: Handler = Arc::new(move |req: &Request| router.handle(req));
        let http = HttpServer::bind(listen, opts, handler)
            .map_err(|e| ServeError::Startup(format!("edge bind {listen}: {e}")))?;
        Ok(EdgeServer { http })
    }

    /// The bound address (resolves an ephemeral port request).
    pub fn local_addr(&self) -> SocketAddr {
        self.http.local_addr()
    }

    /// Stop accepting and join the HTTP threads (in-flight requests get
    /// their responses first — workers only exit between requests).
    pub fn shutdown(self) {
        self.http.shutdown();
    }
}
