//! Wire JSON for the network edge: a hand-rolled encoder plus a lazy
//! partial-field request scanner — no serde, no new crates.
//!
//! Encoding reuses the lossless number/string writers from
//! [`crate::util::json`], so every float on the wire is the shortest
//! round-trippable form: parse it back and you get the same bits. That is
//! what lets the loopback e2e test assert a wire `infer` response is
//! bit-identical to the in-process `Ticket::wait` result.
//!
//! Decoding follows the mik-sdk ADR: the request path never builds a JSON
//! tree. [`scan_infer_batch`] walks the body bytes once, extracts only
//! the fields an inference request needs (`pixels`, `mc_samples`,
//! `defer_threshold`), and skips everything else by token — ~constant
//! work per unknown byte instead of tree allocation. Malformed input of
//! any shape is an `Err` (mapped to HTTP 400 by the router), never a
//! panic: all indexing is bounds-checked and container skipping is
//! iterative (depth-counted), so adversarial nesting cannot blow the
//! stack.

use crate::client::InferResponse;
use crate::coordinator::{MetricsSnapshot, ShardSnapshot};
use crate::util::json::{write_escaped, write_number};

/// A decoded wire inference request (pre-admission: fidelity knobs are
/// still the caller's ask, not the admitted values).
#[derive(Clone, Debug, PartialEq)]
pub struct WireInfer {
    pub pixels: Vec<f32>,
    /// 0 = use the server's configured default (same as `Infer::new`).
    pub mc_samples: usize,
    pub defer_threshold: Option<f64>,
}

/// How the admission policy disposed of a request — carried into the
/// response body so callers can tell a full-fidelity answer from a cheap
/// degraded pass and an escalated re-run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Disposition {
    pub degraded: bool,
    pub escalated: bool,
}

// ---------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------

fn push_key(out: &mut String, first: bool, key: &str) {
    if !first {
        out.push(',');
    }
    write_escaped(out, key);
    out.push(':');
}

fn push_f64_arr(out: &mut String, xs: impl IntoIterator<Item = f64>) {
    out.push('[');
    for (i, x) in xs.into_iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        write_number(out, x);
    }
    out.push(']');
}

/// One `InferResponse` as a wire JSON object. Field set and order are
/// part of the wire format (documented in DESIGN.md §8).
pub fn infer_response_json(resp: &InferResponse, disp: Disposition) -> String {
    let mut o = String::with_capacity(256 + resp.pred.probs.len() * 24);
    o.push('{');
    push_key(&mut o, true, "id");
    write_number(&mut o, resp.id as f64);
    push_key(&mut o, false, "class");
    write_number(&mut o, resp.pred.class as f64);
    push_key(&mut o, false, "confidence");
    write_number(&mut o, resp.pred.confidence);
    push_key(&mut o, false, "probs");
    push_f64_arr(&mut o, resp.pred.probs.iter().copied());
    push_key(&mut o, false, "mc_samples");
    write_number(&mut o, resp.pred.t as f64);
    push_key(&mut o, false, "uncertainty");
    {
        let u = &resp.uncertainty;
        o.push('{');
        push_key(&mut o, true, "entropy");
        write_number(&mut o, u.entropy);
        push_key(&mut o, false, "aleatoric");
        write_number(&mut o, u.aleatoric);
        push_key(&mut o, false, "epistemic");
        write_number(&mut o, u.epistemic);
        push_key(&mut o, false, "threshold");
        write_number(&mut o, u.threshold);
        push_key(&mut o, false, "deferred");
        o.push_str(if u.deferred { "true" } else { "false" });
        o.push('}');
    }
    push_key(&mut o, false, "degraded");
    o.push_str(if disp.degraded { "true" } else { "false" });
    push_key(&mut o, false, "escalated");
    o.push_str(if disp.escalated { "true" } else { "false" });
    push_key(&mut o, false, "latency_ms");
    write_number(&mut o, resp.latency.as_secs_f64() * 1e3);
    push_key(&mut o, false, "batch_id");
    write_number(&mut o, resp.batch_id as f64);
    push_key(&mut o, false, "energy_j");
    write_number(&mut o, resp.energy_j);
    o.push('}');
    o
}

/// A batch of responses: `{"responses": [...]}`.
pub fn infer_batch_json(items: &[(InferResponse, Disposition)]) -> String {
    let mut o = String::from("{\"responses\":[");
    for (i, (resp, disp)) in items.iter().enumerate() {
        if i > 0 {
            o.push(',');
        }
        o.push_str(&infer_response_json(resp, *disp));
    }
    o.push_str("]}");
    o
}

fn shard_json(o: &mut String, s: &ShardSnapshot) {
    o.push('{');
    push_key(o, true, "shard");
    write_number(o, s.shard as f64);
    push_key(o, false, "requests");
    write_number(o, s.requests as f64);
    push_key(o, false, "requests_orphaned");
    write_number(o, s.requests_orphaned as f64);
    push_key(o, false, "requests_shed");
    write_number(o, s.requests_shed as f64);
    push_key(o, false, "requests_degraded");
    write_number(o, s.requests_degraded as f64);
    push_key(o, false, "requests_escalated");
    write_number(o, s.requests_escalated as f64);
    push_key(o, false, "shard_restarts");
    write_number(o, s.shard_restarts as f64);
    push_key(o, false, "requests_retried");
    write_number(o, s.requests_retried as f64);
    push_key(o, false, "requests_failed_shard");
    write_number(o, s.requests_failed_shard as f64);
    push_key(o, false, "batches");
    write_number(o, s.batches as f64);
    push_key(o, false, "mc_passes");
    write_number(o, s.mc_passes as f64);
    push_key(o, false, "engine_executions");
    write_number(o, s.engine_executions as f64);
    push_key(o, false, "epsilon_samples");
    write_number(o, s.epsilon_samples as f64);
    push_key(o, false, "epsilon_fj_per_sample");
    write_number(o, s.epsilon_fj_per_sample());
    push_key(o, false, "gop_per_s");
    write_number(o, s.gop_per_s());
    push_key(o, false, "replicas_active");
    write_number(o, s.replicas_active as f64);
    push_key(o, false, "bytes_shared");
    write_number(o, s.bytes_shared as f64);
    push_key(o, false, "bytes_private");
    write_number(o, s.bytes_private as f64);
    push_key(o, false, "scale_up");
    write_number(o, s.scale_up as f64);
    push_key(o, false, "scale_down");
    write_number(o, s.scale_down as f64);
    push_key(o, false, "work_stolen");
    write_number(o, s.work_stolen as f64);
    push_key(o, false, "model_swaps");
    write_number(o, s.model_swaps as f64);
    o.push('}');
}

/// `GET /v1/metrics` body: the full [`MetricsSnapshot`] as JSON plus the
/// human `render()` text under `"render"`.
pub fn metrics_json(s: &MetricsSnapshot) -> String {
    let mut o = String::with_capacity(1024);
    o.push('{');
    push_key(&mut o, true, "requests_total");
    write_number(&mut o, s.requests_total as f64);
    push_key(&mut o, false, "requests_rejected");
    write_number(&mut o, s.requests_rejected as f64);
    push_key(&mut o, false, "requests_orphaned");
    write_number(&mut o, s.requests_orphaned as f64);
    push_key(&mut o, false, "requests_shed");
    write_number(&mut o, s.requests_shed as f64);
    push_key(&mut o, false, "requests_degraded");
    write_number(&mut o, s.requests_degraded as f64);
    push_key(&mut o, false, "requests_escalated");
    write_number(&mut o, s.requests_escalated as f64);
    push_key(&mut o, false, "shard_restarts");
    write_number(&mut o, s.shard_restarts as f64);
    push_key(&mut o, false, "requests_retried");
    write_number(&mut o, s.requests_retried as f64);
    push_key(&mut o, false, "requests_failed_shard");
    write_number(&mut o, s.requests_failed_shard as f64);
    push_key(&mut o, false, "requests_deferred");
    write_number(&mut o, s.requests_deferred as f64);
    push_key(&mut o, false, "batches");
    write_number(&mut o, s.batches as f64);
    push_key(&mut o, false, "mc_passes");
    write_number(&mut o, s.mc_passes as f64);
    push_key(&mut o, false, "epsilon_samples");
    write_number(&mut o, s.epsilon_samples as f64);
    push_key(&mut o, false, "epsilon_fj_per_sample");
    write_number(&mut o, s.epsilon_fj_per_sample());
    push_key(&mut o, false, "epsilon_gsa_per_s");
    write_number(&mut o, s.epsilon_gsa_per_s());
    push_key(&mut o, false, "gop_per_s");
    write_number(&mut o, s.gop_per_s());
    push_key(&mut o, false, "replicas_active");
    write_number(&mut o, s.replicas_active as f64);
    push_key(&mut o, false, "bytes_shared");
    write_number(&mut o, s.bytes_shared as f64);
    push_key(&mut o, false, "bytes_private");
    write_number(&mut o, s.bytes_private as f64);
    push_key(&mut o, false, "scale_up");
    write_number(&mut o, s.scale_up as f64);
    push_key(&mut o, false, "scale_down");
    write_number(&mut o, s.scale_down as f64);
    push_key(&mut o, false, "work_stolen");
    write_number(&mut o, s.work_stolen as f64);
    push_key(&mut o, false, "model_swaps");
    write_number(&mut o, s.model_swaps as f64);
    push_key(&mut o, false, "latency_p50_ms");
    write_number(&mut o, s.latency_p50_ms);
    push_key(&mut o, false, "latency_p95_ms");
    write_number(&mut o, s.latency_p95_ms);
    push_key(&mut o, false, "throughput_rps");
    write_number(&mut o, s.throughput_rps);
    push_key(&mut o, false, "mean_batch_fill");
    write_number(&mut o, s.mean_batch_fill);
    push_key(&mut o, false, "wall_s");
    write_number(&mut o, s.wall_s);
    push_key(&mut o, false, "per_shard");
    o.push('[');
    for (i, sh) in s.per_shard.iter().enumerate() {
        if i > 0 {
            o.push(',');
        }
        shard_json(&mut o, sh);
    }
    o.push(']');
    push_key(&mut o, false, "render");
    write_escaped(&mut o, &s.render());
    o.push('}');
    o
}

/// Error body: `{"error":{"kind":..,"message":..}}` (+ optional
/// `retry_after_ms` for shed responses).
pub fn error_json(kind: &str, message: &str, retry_after_ms: Option<u64>) -> String {
    let mut o = String::from("{\"error\":{");
    push_key(&mut o, true, "kind");
    write_escaped(&mut o, kind);
    push_key(&mut o, false, "message");
    write_escaped(&mut o, message);
    if let Some(ms) = retry_after_ms {
        push_key(&mut o, false, "retry_after_ms");
        write_number(&mut o, ms as f64);
    }
    o.push_str("}}");
    o
}

// ---------------------------------------------------------------------
// Lazy request scanner
// ---------------------------------------------------------------------

/// Iterative-skip depth bound: far above any legitimate request body,
/// low enough that a hostile `[[[[...` costs only cheap loop iterations.
const MAX_SKIP_DEPTH: usize = 64;

struct Scan<'a> {
    b: &'a [u8],
    pos: usize,
}

type ScanResult<T> = Result<T, String>;

impl<'a> Scan<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b, pos: 0 }
    }

    fn err<T>(&self, msg: &str) -> ScanResult<T> {
        Err(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> ScanResult<()> {
        self.skip_ws();
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", c as char))
        }
    }

    fn at_end(&mut self) -> bool {
        self.skip_ws();
        self.pos >= self.b.len()
    }

    /// Parse a string token and return its unescaped text. Only used for
    /// object keys (we match against known ASCII names); `\uXXXX` escapes
    /// are validated and decoded enough to stay well-formed.
    fn parse_string(&mut self) -> ScanResult<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| "truncated \\u escape".to_string())?;
                            let s = std::str::from_utf8(hex)
                                .map_err(|_| "non-utf8 \\u escape".to_string())?;
                            let cp = u32::from_str_radix(s, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            // Surrogates can't match a known key; U+FFFD
                            // keeps the scan well-formed without pairing.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes; body must be UTF-8.
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    let chunk = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| "non-utf8 string".to_string())?;
                    out.push_str(chunk);
                }
            }
        }
    }

    fn parse_f64(&mut self) -> ScanResult<f64> {
        self.skip_ws();
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        if start == self.pos {
            return self.err("expected number");
        }
        let s = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| "non-utf8 number".to_string())?;
        let x: f64 = s.parse().map_err(|_| format!("bad number '{s}'"))?;
        if !x.is_finite() {
            return Err(format!("non-finite number '{s}'"));
        }
        Ok(x)
    }

    fn parse_usize(&mut self) -> ScanResult<usize> {
        let x = self.parse_f64()?;
        if x < 0.0 || x.fract() != 0.0 || x > u32::MAX as f64 {
            return Err(format!("expected a small non-negative integer, got {x}"));
        }
        Ok(x as usize)
    }

    /// `[1, 2.5, ...]` directly into a `Vec<f32>` — the fast path for
    /// `pixels`, no intermediate tree.
    fn parse_f32_array(&mut self) -> ScanResult<Vec<f32>> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(out);
        }
        loop {
            out.push(self.parse_f64()? as f32);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(out);
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn skip_literal(&mut self, lit: &str) -> ScanResult<()> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(())
        } else {
            self.err("bad literal")
        }
    }

    /// Skip a string token without building its text (for skipped values
    /// and container interiors).
    fn skip_string(&mut self) -> ScanResult<()> {
        self.expect(b'"')?;
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(());
                }
                Some(b'\\') => {
                    // Any escaped byte is consumed blindly; \u needs 4
                    // more bytes but they can't contain an unescaped '"'
                    // we'd miss — hex digits only if valid, and if
                    // invalid the request is malformed anyway and fails
                    // later or terminates harmlessly.
                    self.pos += 2;
                }
                Some(_) => self.pos += 1,
            }
        }
    }

    /// Skip any JSON value iteratively (depth-counted, no recursion), so
    /// adversarial nesting in an unknown field costs cheap loop
    /// iterations instead of stack. Lenient inside skipped containers
    /// (e.g. a trailing comma passes) — this is a skipper, not a
    /// validator; known fields are parsed strictly.
    fn skip_value(&mut self) -> ScanResult<()> {
        let mut depth: usize = 0;
        loop {
            self.skip_ws();
            match self.peek() {
                None => return self.err("truncated value"),
                Some(b'"') => self.skip_string()?,
                Some(b't') => self.skip_literal("true")?,
                Some(b'f') => self.skip_literal("false")?,
                Some(b'n') => self.skip_literal("null")?,
                Some(b'{' | b'[') => {
                    depth += 1;
                    if depth > MAX_SKIP_DEPTH {
                        return self.err("value nested too deeply");
                    }
                    self.pos += 1;
                    continue; // next token is a value (or empty close)
                }
                Some(b'}' | b']') if depth > 0 => {
                    // Empty container closing straight away.
                    depth -= 1;
                    self.pos += 1;
                }
                Some(c) if c.is_ascii_digit() || c == b'-' => {
                    self.parse_f64()?;
                }
                Some(_) => return self.err("unexpected token"),
            }
            if depth == 0 {
                return Ok(());
            }
            // A token was consumed inside a container: unwind closers and
            // separators until the next value position (or the end).
            loop {
                self.skip_ws();
                match self.peek() {
                    Some(b']' | b'}') => {
                        depth -= 1;
                        self.pos += 1;
                        if depth == 0 {
                            return Ok(());
                        }
                    }
                    // ',' precedes the next element (or an object key:
                    // the outer loop consumes it as a string and lands
                    // on the ':' arm below).
                    Some(b',') | Some(b':') => {
                        self.pos += 1;
                        break;
                    }
                    _ => return self.err("bad container"),
                }
            }
        }
    }

    /// Scan one flat request object, extracting only the known fields.
    fn scan_one(&mut self) -> ScanResult<WireInfer> {
        self.expect(b'{')?;
        let mut pixels: Option<Vec<f32>> = None;
        let mut mc_samples = 0usize;
        let mut defer_threshold = None;
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
        } else {
            loop {
                let key = self.parse_string()?;
                self.expect(b':')?;
                match key.as_str() {
                    "pixels" => pixels = Some(self.parse_f32_array()?),
                    "mc_samples" => mc_samples = self.parse_usize()?,
                    "defer_threshold" => defer_threshold = Some(self.parse_f64()?),
                    _ => self.skip_value()?,
                }
                self.skip_ws();
                match self.peek() {
                    Some(b',') => {
                        self.pos += 1;
                        self.skip_ws();
                    }
                    Some(b'}') => {
                        self.pos += 1;
                        break;
                    }
                    _ => return self.err("expected ',' or '}'"),
                }
            }
        }
        let pixels = pixels.ok_or_else(|| "missing required field 'pixels'".to_string())?;
        Ok(WireInfer {
            pixels,
            mc_samples,
            defer_threshold,
        })
    }
}

/// Decode a `POST /v1/infer` body. Two accepted shapes:
///
/// - a single request object `{"pixels": [...], ...}` → one-element vec;
/// - a batch `{"requests": [{...}, {...}]}` → one entry per element
///   (submitted via `submit_many`, preserving batch-fusion semantics).
///
/// Returns `(requests, was_batch)`; `was_batch` picks the response shape.
pub fn scan_infer_batch(body: &[u8]) -> Result<(Vec<WireInfer>, bool), String> {
    let mut s = Scan::new(body);
    s.skip_ws();
    // Disambiguate by the first key: a leading "requests" key means batch.
    // Save/restore position so single-object scanning re-reads the key.
    let start = s.pos;
    s.expect(b'{')?;
    s.skip_ws();
    let is_batch = match s.peek() {
        Some(b'"') => s.parse_string()? == "requests",
        Some(b'}') => false,
        _ => return Err("expected an object key".into()),
    };
    if is_batch {
        s.expect(b':')?;
        s.expect(b'[')?;
        let mut out = Vec::new();
        s.skip_ws();
        if s.peek() == Some(b']') {
            s.pos += 1;
        } else {
            loop {
                out.push(s.scan_one()?);
                s.skip_ws();
                match s.peek() {
                    Some(b',') => s.pos += 1,
                    Some(b']') => {
                        s.pos += 1;
                        break;
                    }
                    _ => return Err("expected ',' or ']' in requests".into()),
                }
            }
        }
        s.expect(b'}')?;
        if !s.at_end() {
            return Err("trailing bytes after batch body".into());
        }
        if out.is_empty() {
            return Err("batch body has no requests".into());
        }
        Ok((out, true))
    } else {
        s.pos = start;
        let one = s.scan_one()?;
        if !s.at_end() {
            return Err("trailing bytes after request body".into());
        }
        Ok((vec![one], false))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scans_single_request_with_unknown_fields() {
        let body = br#" { "client": {"v": [1, {"x": "}"}]}, "pixels": [0.5, -1, 2e-3],
                         "mc_samples": 8, "note": "hi\n\"there\"", "defer_threshold": 0.25 } "#;
        let (reqs, was_batch) = scan_infer_batch(body).unwrap();
        assert!(!was_batch);
        assert_eq!(reqs.len(), 1);
        assert_eq!(reqs[0].pixels, vec![0.5, -1.0, 2e-3]);
        assert_eq!(reqs[0].mc_samples, 8);
        assert_eq!(reqs[0].defer_threshold, Some(0.25));
    }

    #[test]
    fn scans_batch_shape() {
        let body = br#"{"requests": [{"pixels": [1]}, {"pixels": [2], "mc_samples": 4}]}"#;
        let (reqs, was_batch) = scan_infer_batch(body).unwrap();
        assert!(was_batch);
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[0].pixels, vec![1.0]);
        assert_eq!(reqs[0].mc_samples, 0, "absent = server default");
        assert_eq!(reqs[1].mc_samples, 4);
    }

    #[test]
    fn rejects_malformed_without_panicking() {
        let evil: &[&[u8]] = &[
            b"",
            b"{",
            b"[]",
            b"null",
            b"{\"pixels\": }",
            b"{\"pixels\": \"abc\"}",
            b"{\"pixels\": [1,]}",
            b"{\"pixels\": [1] \"x\": 2}",
            b"{\"pixels\": [1]} trailing",
            b"{\"mc_samples\": 4}",
            b"{\"pixels\": [1], \"mc_samples\": -3}",
            b"{\"pixels\": [1], \"mc_samples\": 2.5}",
            b"{\"pixels\": [1e999]}",
            b"{\"requests\": []}",
            b"{\"requests\": [{}]}",
            b"{\"requests\": {\"pixels\": [1]}}",
            b"{\"pixels\": [1], \"x\": \xff\xfe}",
            b"{\"pixels\": [NaN]}",
        ];
        for body in evil {
            assert!(
                scan_infer_batch(body).is_err(),
                "accepted malformed body {:?}",
                String::from_utf8_lossy(body)
            );
        }
        // Hostile nesting in a *skipped* field: error, not a stack blow.
        let mut deep = br#"{"pixels": [1], "junk": "#.to_vec();
        deep.extend(std::iter::repeat(b'[').take(100_000));
        assert!(scan_infer_batch(&deep).is_err());
    }

    #[test]
    fn skips_nested_unknown_values() {
        let body = br#"{"a": {"b": [1, [2, {"c": null}], "]}"], "d": true},
                       "pixels": [3], "e": false}"#;
        let (reqs, _) = scan_infer_batch(body).unwrap();
        assert_eq!(reqs[0].pixels, vec![3.0]);
    }
}
