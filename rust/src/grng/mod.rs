//! The in-word GRNG subsystem (§III-C): physics model, behavioral circuit
//! simulation, die-level mismatch Monte Carlo, the per-tile GRNG bank,
//! output-quality statistics, and the comparison baselines of Tab. II.

pub mod bank;
pub mod baselines;
pub mod circuit;
pub mod mismatch;
pub mod physics;
pub mod quality;

pub use bank::{shard_chip, shard_die_seed, GrngBank};
pub use circuit::{CellParams, GrngCell, GrngSample};
pub use mismatch::DieVariation;
pub use quality::QualityReport;
