//! Closed-form physics of the capacitor-discharge GRNG (§III-C, Eq. 6–7).
//!
//! The entropy source: a ~1 fF capacitor charged to V_DD discharges through
//! an NMOS biased in subthreshold at V_R. Charge leaves in discrete
//! electrons (shot noise, PSD 2·q_e·I), so the time T at which the voltage
//! crosses the inverter threshold V_Thr is Gaussian:
//!
//! ```text
//! μ_T  = C·(V_DD − V_Thr) / I_L            (paper Eq. 6, explicit V_Thr)
//! σ_T² = μ_T · q_e / (2·I_L) · κ           (paper Eq. 7)
//! ```
//!
//! Two additional measured effects are modeled (they drive Tab. I):
//!
//! - **kTC noise**: the sampled initial voltage carries σ_V = √(kT/C),
//!   contributing σ_T,kTC = C·σ_V / I_L of crossing-time jitter.
//! - **RTN** (random telegraph noise): single-trap capture/emission in the
//!   subthreshold channel modulates I_L by a relative amplitude that grows
//!   with temperature (Arrhenius-activated). This term dominates at the
//!   low-bias/long-latency operating points of Tab. I and explains why the
//!   measured pulse-width σ *increases* 2.62× from 28 °C to 60 °C while
//!   latency *decreases* 2.49× — pure shot noise would predict both falling.
//!
//! Temperature enters the mean through the subthreshold law
//! I_L ∝ (T/T₀)²·exp((V_R − V_th(T))/(n·v_T)) with v_T = kT/q and
//! dV_th/dT < 0, so leakage rises steeply with temperature.

use crate::config::GrngConfig;

/// Boltzmann constant [J/K].
pub const K_B: f64 = 1.380649e-23;
/// Elementary charge \[C\].
pub const Q_E: f64 = 1.602176634e-19;
/// Reference temperature for I_0 calibration \[K\] (28 °C).
pub const T_REF_K: f64 = 301.15;

/// Thermal voltage kT/q \[V\].
#[inline]
pub fn thermal_voltage(temp_k: f64) -> f64 {
    K_B * temp_k / Q_E
}

/// Subthreshold leakage current of one discharge branch \[A\].
///
/// `delta_vth` is the per-device static mismatch on the threshold voltage
/// (Eq. 8's origin); positive `delta_vth` → less current.
pub fn leakage_current(cfg: &GrngConfig, bias_v: f64, temp_k: f64, delta_vth: f64) -> f64 {
    let v_t = thermal_voltage(temp_k);
    let vth_t = cfg.v_th + cfg.v_th_tc * (temp_k - T_REF_K) + delta_vth;
    let exponent = (bias_v - vth_t) / (cfg.subthreshold_n * v_t);
    cfg.i0_a * (temp_k / T_REF_K).powi(2) * exponent.exp()
}

/// Mean crossing time μ_T \[s\] (Eq. 6).
pub fn mean_crossing_time(cfg: &GrngConfig, i_leak: f64) -> f64 {
    cfg.cap_f * (cfg.vdd - cfg.v_thr) / i_leak
}

/// Shot-noise crossing-time standard deviation \[s\] (Eq. 7, with the
/// configurable calibration scale κ).
pub fn shot_sigma(cfg: &GrngConfig, mu_t: f64, i_leak: f64) -> f64 {
    (mu_t * Q_E / (2.0 * i_leak) * cfg.noise_scale).sqrt()
}

/// kTC-noise contribution to crossing-time σ \[s\]: sampled initial-voltage
/// noise √(kT/C) divided by the ramp slope I/C.
pub fn ktc_sigma(cfg: &GrngConfig, temp_k: f64, i_leak: f64) -> f64 {
    let sigma_v = (K_B * temp_k / cfg.cap_f).sqrt();
    cfg.cap_f * sigma_v / i_leak
}

/// RTN/flicker relative amplitude at temperature `temp_k`:
/// a(T) = a₀ · exp((T − T₀)/T_scale). Trap occupancy fluctuations are
/// thermally activated, so low-frequency noise grows steeply with
/// temperature — this is what makes the measured pulse-width σ *rise*
/// 2.62× from 28 °C to 60 °C (Tab. I) while the latency falls.
pub fn rtn_amplitude(cfg: &GrngConfig, temp_k: f64) -> f64 {
    cfg.rtn_rel_amplitude * ((temp_k - T_REF_K) / cfg.rtn_t_scale_k).exp()
}

/// Probability that a sample is an outlier (trap burst coinciding with the
/// DFF asynchronous reset, §III-C.2) — responsible for the Q–Q r-value
/// collapse at 60 °C in Tab. I.
pub fn outlier_probability(cfg: &GrngConfig, temp_k: f64) -> f64 {
    (cfg.outlier_p0 * ((temp_k - T_REF_K) / cfg.outlier_t_scale_k).exp()).min(0.5)
}

/// Outlier magnitude multiplier. Magnitude is kept temperature-flat:
/// the Tab. I degradation is reproduced by the *probability* onset
/// (sharp 2 K activation scale), which both bumps the measured pulse-σ
/// (×~1.4 at 60 °C) and drags the Q-Q r-value down without the gross
/// distribution blow-up a magnitude explosion would cause.
pub fn outlier_magnitude_scale(_cfg: &GrngConfig, _temp_k: f64) -> f64 {
    1.0
}

/// RTN/flicker contribution to crossing-time σ \[s\].
///
/// Low-frequency noise accumulates superlinearly with integration time:
/// σ_rtn/μ_T = a(T) · (μ_T/τ_ref)^p. Fitted to Tab. I (p ≈ 0.7): at the
/// 69 ns typical point it contributes < 1 % relative jitter; at the
/// 1.93 µs low-bias point it dominates (~7 % relative, → 200 ns pulse σ).
pub fn rtn_sigma(cfg: &GrngConfig, temp_k: f64, mu_t: f64) -> f64 {
    let a = rtn_amplitude(cfg, temp_k);
    a * mu_t * (mu_t / cfg.rtn_tau_s).powf(cfg.rtn_exponent)
}

/// Total single-branch crossing-time σ \[s\]: independent contributions add
/// in quadrature.
pub fn total_sigma(cfg: &GrngConfig, temp_k: f64, mu_t: f64, i_leak: f64) -> f64 {
    let s2 = shot_sigma(cfg, mu_t, i_leak).powi(2)
        + ktc_sigma(cfg, temp_k, i_leak).powi(2)
        + rtn_sigma(cfg, temp_k, mu_t).powi(2);
    s2.sqrt()
}

/// Closed-form operating point at (bias, temperature): the quantities the
/// paper measures in Fig. 8/9 and Tab. I.
#[derive(Clone, Copy, Debug)]
pub struct OperatingPoint {
    pub bias_v: f64,
    pub temp_c: f64,
    /// Per-branch leakage current \[A\].
    pub i_leak: f64,
    /// Mean single-branch crossing time (≈ average latency) \[s\].
    pub mu_t: f64,
    /// Pulse-width standard deviation \[s\]: √2 × single-branch σ (the pulse
    /// is the *difference* of two independent crossings).
    pub pulse_sigma: f64,
    /// Energy per sample \[J\].
    pub energy_j: f64,
}

/// Compute the closed-form operating point for a config at its configured
/// bias/temperature (or overridden values).
pub fn operating_point(cfg: &GrngConfig, bias_v: f64, temp_c: f64) -> OperatingPoint {
    let temp_k = temp_c + 273.15;
    let i_leak = leakage_current(cfg, bias_v, temp_k, 0.0);
    let mu_t = mean_crossing_time(cfg, i_leak);
    let sigma_1 = total_sigma(cfg, temp_k, mu_t, i_leak);
    OperatingPoint {
        bias_v,
        temp_c,
        i_leak,
        mu_t,
        pulse_sigma: core::f64::consts::SQRT_2 * sigma_1,
        energy_j: energy_per_sample(cfg, i_leak),
    }
}

/// Energy per GRNG sample \[J\] (§III-C.2):
/// - recharging both fringe caps: 2·C·V_DD²
/// - inverter short-circuit while V_C crosses V_Thr: ∝ C/I_L (slower ramp
///   → longer conduction window) — the dominant term, mitigated but not
///   eliminated by the asynchronous-reset DFF
/// - DFF reset + latch energy (fixed digital cost)
pub fn energy_per_sample(cfg: &GrngConfig, i_leak: f64) -> f64 {
    let caps = 2.0 * cfg.cap_f * cfg.vdd * cfg.vdd;
    let inverter = cfg.inverter_sc_coeff / i_leak;
    caps + inverter + cfg.dff_energy_j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> GrngConfig {
        GrngConfig::default()
    }

    #[test]
    fn typical_operating_point_matches_paper() {
        // Paper §IV-A: V_R = 180 mV → σ ≈ 1.0 ns pulse width, ~69 ns
        // average latency, 360 fJ/Sample.
        let op = operating_point(&cfg(), 0.18, 28.0);
        assert!(
            (op.mu_t - 69e-9).abs() < 12e-9,
            "latency {:.1} ns should be ≈69 ns",
            op.mu_t * 1e9
        );
        assert!(
            (op.pulse_sigma - 1.0e-9).abs() < 0.35e-9,
            "pulse σ {:.2} ns should be ≈1.0 ns",
            op.pulse_sigma * 1e9
        );
        assert!(
            (op.energy_j - 360e-15).abs() < 60e-15,
            "energy {:.0} fJ should be ≈360 fJ",
            op.energy_j * 1e15
        );
    }

    #[test]
    fn bias_tradeoff_direction() {
        // Fig. 9: increasing V_R decreases latency AND decreases σ.
        let lo = operating_point(&cfg(), 0.12, 28.0);
        let hi = operating_point(&cfg(), 0.20, 28.0);
        assert!(hi.mu_t < lo.mu_t, "higher bias → lower latency");
        assert!(hi.pulse_sigma < lo.pulse_sigma, "higher bias → lower σ");
        assert!(hi.energy_j < lo.energy_j, "higher bias → lower energy");
    }

    #[test]
    fn temperature_dependence_matches_table1_directions() {
        // Tab. I trends at the low-bias measurement point (long latencies):
        // 28→60 °C: latency ÷2.49, pulse σ ×2.62.
        let c = cfg();
        // Find the bias giving ≈1.93 µs latency at 28 °C (Tab. I row 1).
        let bias = find_bias_for_latency(&c, 1.931e-6, 28.0);
        let cold = operating_point(&c, bias, 28.0);
        let hot = operating_point(&c, bias, 60.0);
        let latency_ratio = cold.mu_t / hot.mu_t;
        let sigma_ratio = hot.pulse_sigma / cold.pulse_sigma;
        assert!(
            (2.0..=3.6).contains(&latency_ratio),
            "latency ratio {latency_ratio:.2} should be ≈2.49"
        );
        // Closed form excludes the outlier-burst variance that the
        // measured Tab. I σ includes (×~1.4 at 60 °C) — so the physics
        // band sits below the paper's 2.62 measured ratio.
        assert!(
            (1.3..=3.8).contains(&sigma_ratio),
            "sigma ratio {sigma_ratio:.2} must INCREASE toward ≈2.62/1.4"
        );
    }

    /// Bisection for the bias voltage that hits a target latency.
    pub(crate) fn find_bias_for_latency(cfg: &GrngConfig, target_s: f64, temp_c: f64) -> f64 {
        let (mut lo, mut hi) = (0.0, 0.5);
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            let op = operating_point(cfg, mid, temp_c);
            if op.mu_t > target_s {
                lo = mid; // need more current → higher bias
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }

    #[test]
    fn leakage_monotonic_in_bias_and_temp() {
        let c = cfg();
        let i1 = leakage_current(&c, 0.10, 300.0, 0.0);
        let i2 = leakage_current(&c, 0.20, 300.0, 0.0);
        let i3 = leakage_current(&c, 0.10, 330.0, 0.0);
        assert!(i2 > i1);
        assert!(i3 > i1);
        // mismatch reduces current for positive ΔVth
        assert!(leakage_current(&c, 0.10, 300.0, 0.02) < i1);
    }

    #[test]
    fn energy_components_positive_and_dominated_by_inverter() {
        let c = cfg();
        let op = operating_point(&c, 0.18, 28.0);
        let caps = 2.0 * c.cap_f * c.vdd * c.vdd;
        assert!(caps < 5e-15);
        assert!(op.energy_j > 100e-15, "inverter term should dominate");
    }
}
