//! Die-level static variation Monte Carlo (§III-C.3).
//!
//! Fabrication-induced transistor mismatch makes each GRNG cell's two
//! branches conduct slightly differently, shifting the output mean by a
//! static per-cell offset ε₀ (Eq. 8). The offsets are fixed per die —
//! drawn once from the process distribution and then constant — which is
//! exactly what makes the one-time calibration of Eq. 9–10 possible.

use crate::config::GrngConfig;
use crate::grng::circuit::CellParams;
use crate::util::rng::{Pcg64, Rng64};

/// Static mismatch for every GRNG cell of a die (row-major `rows × words`).
#[derive(Clone, Debug)]
pub struct DieVariation {
    pub rows: usize,
    pub words: usize,
    /// Per-cell ΔVth for the P branch \[V\].
    pub dvth_p: Vec<f64>,
    /// Per-cell ΔVth for the N branch \[V\].
    pub dvth_n: Vec<f64>,
}

impl DieVariation {
    /// Draw a die. `seed` identifies the die; the same seed always yields
    /// the same silicon (mismatch is static).
    ///
    /// ΔVth σ is derived from the configured relative current mismatch:
    /// in subthreshold, ΔI/I = ΔVth/(n·v_T), so
    /// σ_Vth = mismatch_rel_sigma · n · v_T.
    pub fn draw(cfg: &GrngConfig, rows: usize, words: usize, seed: u64) -> Self {
        let v_t = crate::grng::physics::thermal_voltage(cfg.temp_k());
        let sigma_vth = cfg.mismatch_rel_sigma * cfg.subthreshold_n * v_t;
        let mut rng = Pcg64::with_stream(seed, 0x5EED_D1E5);
        let n = rows * words;
        let dvth_p = (0..n).map(|_| sigma_vth * rng.next_gaussian()).collect();
        let dvth_n = (0..n).map(|_| sigma_vth * rng.next_gaussian()).collect();
        Self {
            rows,
            words,
            dvth_p,
            dvth_n,
        }
    }

    /// A perfect die (no mismatch) — for ablations.
    pub fn ideal(rows: usize, words: usize) -> Self {
        Self {
            rows,
            words,
            dvth_p: vec![0.0; rows * words],
            dvth_n: vec![0.0; rows * words],
        }
    }

    #[inline]
    pub fn index(&self, row: usize, word: usize) -> usize {
        debug_assert!(row < self.rows && word < self.words);
        row * self.words + word
    }

    /// Derive the cell parameters for cell (row, word).
    pub fn cell_params(&self, cfg: &GrngConfig, row: usize, word: usize) -> CellParams {
        let i = self.index(row, word);
        CellParams::derive(cfg, self.dvth_p[i], self.dvth_n[i])
    }

    /// The true ε₀ offset map of the die (what calibration must estimate).
    pub fn offset_map(&self, cfg: &GrngConfig) -> Vec<f64> {
        (0..self.rows * self.words)
            .map(|i| CellParams::derive(cfg, self.dvth_p[i], self.dvth_n[i]).epsilon_offset())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    #[test]
    fn same_seed_same_die() {
        let cfg = GrngConfig::default();
        let a = DieVariation::draw(&cfg, 8, 4, 42);
        let b = DieVariation::draw(&cfg, 8, 4, 42);
        assert_eq!(a.dvth_p, b.dvth_p);
        let c = DieVariation::draw(&cfg, 8, 4, 43);
        assert_ne!(a.dvth_p, c.dvth_p);
    }

    #[test]
    fn offsets_are_zero_mean_and_spread() {
        let cfg = GrngConfig::default();
        let die = DieVariation::draw(&cfg, 64, 8, 7);
        let offsets = die.offset_map(&cfg);
        let s = Summary::from_slice(&offsets);
        // Eq. 8: nonzero per-cell offsets, zero-mean across the die.
        assert!(s.std() > 0.1, "σ(ε₀)={} should be significant", s.std());
        assert!(
            s.mean().abs() < 3.0 * s.std() / (offsets.len() as f64).sqrt() + 0.05,
            "die-average offset should be ~0, got {}",
            s.mean()
        );
    }

    #[test]
    fn ideal_die_has_no_offsets() {
        let cfg = GrngConfig::default();
        let die = DieVariation::ideal(4, 4);
        for off in die.offset_map(&cfg) {
            assert_eq!(off, 0.0);
        }
    }

    #[test]
    fn index_layout() {
        let die = DieVariation::ideal(3, 5);
        assert_eq!(die.index(0, 0), 0);
        assert_eq!(die.index(1, 0), 5);
        assert_eq!(die.index(2, 4), 14);
    }
}
