//! The in-word GRNG bank: one GRNG cell per σε word of the CIM tile.
//!
//! This is the architectural point of the paper: ε is generated *inside*
//! the memory word that stores σ, so a full 64×8 matrix of fresh Gaussian
//! samples materializes in one conversion — no reads, no writes, no RNG
//! unit on the far side of a bus. The bank exposes:
//!
//! - [`GrngBank::fill_epsilon`] — one fresh ε per cell (one MVM's worth),
//! - per-cell offsets for the calibration controller,
//! - aggregate throughput/energy accounting for Tab. II.

use crate::config::{ChipConfig, GrngConfig};
use crate::grng::circuit::GrngCell;
use crate::grng::mismatch::DieVariation;
use crate::util::rng::{Rng64, SplitMix64};

/// Bank of GRNG cells matching a tile's σε array layout.
pub struct GrngBank {
    pub rows: usize,
    pub words: usize,
    cells: Vec<GrngCell>,
    /// Total samples drawn (for energy/throughput accounting).
    samples_drawn: u64,
}

impl GrngBank {
    /// Build the bank for a die.
    pub fn new(cfg: &GrngConfig, die: &DieVariation, seed: u64) -> Self {
        let mut seeder = SplitMix64::new(seed ^ 0x6BA4_57B1);
        let cells = (0..die.rows * die.words)
            .map(|i| {
                let row = i / die.words;
                let word = i % die.words;
                GrngCell::new(die.cell_params(cfg, row, word), seeder.split())
            })
            .collect();
        Self {
            rows: die.rows,
            words: die.words,
            cells,
            samples_drawn: 0,
        }
    }

    /// Convenience: bank for the configured chip with its die seed.
    pub fn for_chip(chip: &ChipConfig) -> Self {
        let die = DieVariation::draw(
            &chip.grng,
            chip.tile.rows,
            chip.tile.words_per_row,
            chip.die_seed,
        );
        Self::new(&chip.grng, &die, chip.die_seed)
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    #[inline]
    pub fn cell(&self, row: usize, word: usize) -> &GrngCell {
        &self.cells[row * self.words + word]
    }

    #[inline]
    pub fn cell_mut(&mut self, row: usize, word: usize) -> &mut GrngCell {
        &mut self.cells[row * self.words + word]
    }

    /// Fill `out` (len = rows × words, row-major) with one fresh ε per
    /// cell — the parallel sampling that accompanies every MVM. Uses the
    /// fast closed-form path.
    pub fn fill_epsilon(&mut self, out: &mut [f64]) {
        assert_eq!(out.len(), self.cells.len());
        for (o, cell) in out.iter_mut().zip(self.cells.iter_mut()) {
            *o = cell.eps_fast();
        }
        self.samples_drawn += self.cells.len() as u64;
    }

    /// Allocate-and-fill variant.
    pub fn epsilon_matrix(&mut self) -> Vec<f64> {
        let mut out = vec![0.0; self.cells.len()];
        self.fill_epsilon(&mut out);
        out
    }

    /// True per-cell static offsets (ground truth for calibration tests).
    pub fn true_offsets(&self) -> Vec<f64> {
        self.cells
            .iter()
            .map(|c| c.params.epsilon_offset())
            .collect()
    }

    /// Mean per-sample energy across the bank [J].
    pub fn mean_energy_per_sample(&self) -> f64 {
        let total: f64 = self.cells.iter().map(|c| c.params.energy_j).sum();
        total / self.cells.len() as f64
    }

    /// Mean conversion latency (≈ slowest-branch mean) across the bank [s].
    pub fn mean_latency(&self) -> f64 {
        let total: f64 = self
            .cells
            .iter()
            .map(|c| c.params.mu_p.max(c.params.mu_n))
            .sum();
        total / self.cells.len() as f64
    }

    /// Aggregate hardware sample throughput [Sa/s]: all cells convert in
    /// parallel, one sample per cell per conversion. (The paper's
    /// 5.12 GSa/s: 512 cells ÷ ~100 ns cycle.)
    pub fn hardware_throughput_sa_s(&self) -> f64 {
        let latency = self.mean_latency() + self.cells[0].params.cfg.dff_reset_window_s * 2.0;
        self.cells.len() as f64 / latency
    }

    pub fn samples_drawn(&self) -> u64 {
        self.samples_drawn
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;
    use crate::util::stats::Summary;

    #[test]
    fn bank_fills_full_matrix() {
        let chip = ChipConfig::default();
        let mut bank = GrngBank::for_chip(&chip);
        assert_eq!(bank.len(), 512);
        let eps = bank.epsilon_matrix();
        assert_eq!(eps.len(), 512);
        assert_eq!(bank.samples_drawn(), 512);
        // Not all equal (actual randomness).
        let s = Summary::from_slice(&eps);
        assert!(s.std() > 0.5);
    }

    #[test]
    fn bank_throughput_near_paper() {
        // Paper: 5.12 GSa/s from 512 parallel cells.
        let chip = ChipConfig::default();
        let bank = GrngBank::for_chip(&chip);
        let tput = bank.hardware_throughput_sa_s();
        assert!(
            (3.0e9..9.0e9).contains(&tput),
            "throughput {tput:.3e} should be in the GSa/s range"
        );
    }

    #[test]
    fn bank_energy_near_paper() {
        let chip = ChipConfig::default();
        let bank = GrngBank::for_chip(&chip);
        let e = bank.mean_energy_per_sample();
        assert!(
            (260e-15..460e-15).contains(&e),
            "energy/sample {:.0} fJ should be ≈360 fJ",
            e * 1e15
        );
    }

    #[test]
    fn different_cells_have_different_offsets() {
        let chip = ChipConfig::default();
        let bank = GrngBank::for_chip(&chip);
        let offs = bank.true_offsets();
        let s = Summary::from_slice(&offs);
        assert!(s.std() > 0.05, "mismatch must spread offsets, σ={}", s.std());
    }

    #[test]
    fn deterministic_per_die_seed() {
        let chip = ChipConfig::default();
        let mut a = GrngBank::for_chip(&chip);
        let mut b = GrngBank::for_chip(&chip);
        assert_eq!(a.epsilon_matrix(), b.epsilon_matrix());
        let mut chip2 = ChipConfig::default();
        chip2.die_seed = 1;
        let mut c = GrngBank::for_chip(&chip2);
        assert_ne!(a.epsilon_matrix(), c.epsilon_matrix());
    }
}
