//! The in-word GRNG bank: one GRNG cell per σε word of the CIM tile.
//!
//! This is the architectural point of the paper: ε is generated *inside*
//! the memory word that stores σ, so a full 64×8 matrix of fresh Gaussian
//! samples materializes in one conversion — no reads, no writes, no RNG
//! unit on the far side of a bus. The bank exposes:
//!
//! - [`GrngBank::fill_epsilon`] — one fresh ε per cell (one MVM's worth),
//! - per-cell offsets for the calibration controller,
//! - aggregate throughput/energy accounting for Tab. II.

use crate::config::{ChipConfig, GrngConfig};
use crate::grng::circuit::GrngCell;
use crate::grng::mismatch::DieVariation;
use crate::util::rng::SplitMix64;

/// Derive the die seed for shard `shard` of a sharded serving pool.
///
/// Shard 0 keeps `die_seed` unchanged, so a single-shard pool draws the
/// exact ε stream of an unsharded bank (bit-for-bit). Higher shards get
/// independent SplitMix64-split streams — the software mirror of
/// replicating the in-word GRNG bank per compute lane (cf. VIBNN's
/// parallel RNG banks): statistically independent ε, reproducible for a
/// fixed `(die_seed, workers)` pair.
pub fn shard_die_seed(die_seed: u64, shard: usize) -> u64 {
    if shard == 0 {
        return die_seed;
    }
    let mut splitter = SplitMix64::new(die_seed ^ 0xD1E5_EED5_0F5A_A5F1);
    let mut seed = die_seed;
    for _ in 0..shard {
        seed = splitter.split();
    }
    seed
}

/// Chip config for shard `shard` of a serving pool: the same die family
/// with its seed split by [`shard_die_seed`]. The single home of the
/// reseed idiom, shared by [`GrngBank::for_shard`] and the coordinator's
/// `GrngBankSource::for_shard`.
pub fn shard_chip(chip: &ChipConfig, shard: usize) -> ChipConfig {
    let mut chip = chip.clone();
    chip.die_seed = shard_die_seed(chip.die_seed, shard);
    chip
}

/// Bank of GRNG cells matching a tile's σε array layout.
#[derive(Clone)]
pub struct GrngBank {
    pub rows: usize,
    pub words: usize,
    cells: Vec<GrngCell>,
    /// Total samples drawn (for energy/throughput accounting).
    samples_drawn: u64,
}

impl GrngBank {
    /// Build the bank for a die.
    pub fn new(cfg: &GrngConfig, die: &DieVariation, seed: u64) -> Self {
        let mut seeder = SplitMix64::new(seed ^ 0x6BA4_57B1);
        let cells = (0..die.rows * die.words)
            .map(|i| {
                let row = i / die.words;
                let word = i % die.words;
                GrngCell::new(die.cell_params(cfg, row, word), seeder.split())
            })
            .collect();
        Self {
            rows: die.rows,
            words: die.words,
            cells,
            samples_drawn: 0,
        }
    }

    /// Convenience: bank for the configured chip with its die seed.
    pub fn for_chip(chip: &ChipConfig) -> Self {
        let die = DieVariation::draw(
            &chip.grng,
            chip.tile.rows,
            chip.tile.words_per_row,
            chip.die_seed,
        );
        Self::new(&chip.grng, &die, chip.die_seed)
    }

    /// Bank for shard `shard` of a serving pool: an independent simulated
    /// die seeded by [`shard_die_seed`]. Shard 0 is the chip's own die.
    pub fn for_shard(chip: &ChipConfig, shard: usize) -> Self {
        Self::for_chip(&shard_chip(chip, shard))
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    #[inline]
    pub fn cell(&self, row: usize, word: usize) -> &GrngCell {
        &self.cells[row * self.words + word]
    }

    #[inline]
    pub fn cell_mut(&mut self, row: usize, word: usize) -> &mut GrngCell {
        &mut self.cells[row * self.words + word]
    }

    /// Fill `out` (len = rows × words, row-major) with one fresh ε per
    /// cell — the parallel sampling that accompanies every MVM. Uses the
    /// fast closed-form path.
    pub fn fill_epsilon(&mut self, out: &mut [f64]) {
        assert_eq!(out.len(), self.cells.len());
        for (o, cell) in out.iter_mut().zip(self.cells.iter_mut()) {
            *o = cell.eps_fast();
        }
        self.samples_drawn += self.cells.len() as u64;
    }

    /// Allocate-and-fill variant.
    pub fn epsilon_matrix(&mut self) -> Vec<f64> {
        let mut out = vec![0.0; self.cells.len()];
        self.fill_epsilon(&mut out);
        out
    }

    /// True per-cell static offsets (ground truth for calibration tests).
    pub fn true_offsets(&self) -> Vec<f64> {
        self.cells
            .iter()
            .map(|c| c.params.epsilon_offset())
            .collect()
    }

    /// Reseed every cell's sampling stream from SplitMix64 splits of
    /// `seed`, keeping the die's physics (mismatch, energy, latency).
    /// With [`GrngCell::reseed`], this is how an MC-parallel replica of a
    /// calibrated tile gets an independent ε stream on the *same* die.
    pub fn reseed_cells(&mut self, seed: u64) {
        let mut seeder = SplitMix64::new(seed ^ 0x6BA4_57B1);
        for cell in &mut self.cells {
            cell.reseed(seeder.split());
        }
    }

    /// Mean per-sample energy across the bank [J]; 0.0 for an empty bank.
    pub fn mean_energy_per_sample(&self) -> f64 {
        if self.cells.is_empty() {
            return 0.0;
        }
        let total: f64 = self.cells.iter().map(|c| c.params.energy_j).sum();
        total / self.cells.len() as f64
    }

    /// Mean conversion latency (≈ slowest-branch mean) across the bank
    /// [s]; 0.0 for an empty bank.
    pub fn mean_latency(&self) -> f64 {
        if self.cells.is_empty() {
            return 0.0;
        }
        let total: f64 = self
            .cells
            .iter()
            .map(|c| c.params.mu_p.max(c.params.mu_n))
            .sum();
        total / self.cells.len() as f64
    }

    /// Aggregate hardware sample throughput [Sa/s]: all cells convert in
    /// parallel, one sample per cell per conversion. (The paper's
    /// 5.12 GSa/s: 512 cells ÷ ~100 ns cycle.) An empty bank produces no
    /// samples: 0.0, not a panic.
    pub fn hardware_throughput_sa_s(&self) -> f64 {
        let Some(first) = self.cells.first() else {
            return 0.0;
        };
        let latency = self.mean_latency() + first.params.cfg.dff_reset_window_s * 2.0;
        if latency <= 0.0 {
            return 0.0;
        }
        self.cells.len() as f64 / latency
    }

    pub fn samples_drawn(&self) -> u64 {
        self.samples_drawn
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;
    use crate::util::stats::Summary;

    #[test]
    fn bank_fills_full_matrix() {
        let chip = ChipConfig::default();
        let mut bank = GrngBank::for_chip(&chip);
        assert_eq!(bank.len(), 512);
        let eps = bank.epsilon_matrix();
        assert_eq!(eps.len(), 512);
        assert_eq!(bank.samples_drawn(), 512);
        // Not all equal (actual randomness).
        let s = Summary::from_slice(&eps);
        assert!(s.std() > 0.5);
    }

    #[test]
    fn bank_throughput_near_paper() {
        // Paper: 5.12 GSa/s from 512 parallel cells.
        let chip = ChipConfig::default();
        let bank = GrngBank::for_chip(&chip);
        let tput = bank.hardware_throughput_sa_s();
        assert!(
            (3.0e9..9.0e9).contains(&tput),
            "throughput {tput:.3e} should be in the GSa/s range"
        );
    }

    #[test]
    fn bank_energy_near_paper() {
        let chip = ChipConfig::default();
        let bank = GrngBank::for_chip(&chip);
        let e = bank.mean_energy_per_sample();
        assert!(
            (260e-15..460e-15).contains(&e),
            "energy/sample {:.0} fJ should be ≈360 fJ",
            e * 1e15
        );
    }

    #[test]
    fn different_cells_have_different_offsets() {
        let chip = ChipConfig::default();
        let bank = GrngBank::for_chip(&chip);
        let offs = bank.true_offsets();
        let s = Summary::from_slice(&offs);
        assert!(s.std() > 0.05, "mismatch must spread offsets, σ={}", s.std());
    }

    #[test]
    fn empty_bank_reports_zero_not_panic() {
        let chip = ChipConfig::default();
        let die = crate::grng::DieVariation::draw(&chip.grng, 0, 0, 1);
        let mut bank = GrngBank::new(&chip.grng, &die, 1);
        assert!(bank.is_empty());
        assert_eq!(bank.len(), 0);
        assert_eq!(bank.hardware_throughput_sa_s(), 0.0);
        assert_eq!(bank.mean_energy_per_sample(), 0.0);
        assert_eq!(bank.mean_latency(), 0.0);
        let mut out: [f64; 0] = [];
        bank.fill_epsilon(&mut out);
        assert_eq!(bank.samples_drawn(), 0);
    }

    #[test]
    fn reseeded_cells_draw_new_streams_on_same_die() {
        let chip = ChipConfig::default();
        let mut a = GrngBank::for_chip(&chip);
        let mut b = GrngBank::for_chip(&chip);
        b.reseed_cells(0xD1CE);
        assert_eq!(a.true_offsets(), b.true_offsets(), "same die physics");
        let eps_b = b.epsilon_matrix();
        assert_ne!(a.epsilon_matrix(), eps_b, "new streams");
        let mut c = GrngBank::for_chip(&chip);
        c.reseed_cells(0xD1CE);
        assert_eq!(eps_b, c.epsilon_matrix(), "deterministic reseed");
    }

    #[test]
    fn shard_banks_are_independent_dies() {
        let chip = ChipConfig::default();
        assert_eq!(shard_die_seed(chip.die_seed, 0), chip.die_seed);
        let mut a = GrngBank::for_shard(&chip, 0);
        let mut b = GrngBank::for_chip(&chip);
        assert_eq!(a.epsilon_matrix(), b.epsilon_matrix());
        let mut c = GrngBank::for_shard(&chip, 1);
        let mut d = GrngBank::for_shard(&chip, 2);
        let ec = c.epsilon_matrix();
        assert_ne!(ec, d.epsilon_matrix());
        assert_ne!(ec, a.epsilon_matrix());
    }

    #[test]
    fn deterministic_per_die_seed() {
        let chip = ChipConfig::default();
        let mut a = GrngBank::for_chip(&chip);
        let mut b = GrngBank::for_chip(&chip);
        assert_eq!(a.epsilon_matrix(), b.epsilon_matrix());
        let mut chip2 = ChipConfig::default();
        chip2.die_seed = 1;
        let mut c = GrngBank::for_chip(&chip2);
        assert_ne!(a.epsilon_matrix(), c.epsilon_matrix());
    }
}
