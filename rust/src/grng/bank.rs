//! The in-word GRNG bank: one GRNG cell per σε word of the CIM tile.
//!
//! This is the architectural point of the paper: ε is generated *inside*
//! the memory word that stores σ, so a full 64×8 matrix of fresh Gaussian
//! samples materializes in one conversion — no reads, no writes, no RNG
//! unit on the far side of a bus. The bank exposes:
//!
//! - [`GrngBank::fill_epsilon`] — one fresh ε per cell (one MVM's worth),
//! - [`GrngBank::fill_epsilon_planes`] — the same conversion written
//!   directly into the plane-major `[word][row]` layout the CIM tile's
//!   SoA MVM fast path consumes (no row-major intermediate),
//! - per-cell offsets for the calibration controller,
//! - aggregate throughput/energy accounting for Tab. II.
//!
//! §Perf — block sampling layout. The pre-PR bank walked a
//! `Vec<GrngCell>` of AoS structs: every draw chased a ~300-byte cell
//! (params embed a full `GrngConfig`) and ran the branchy scalar
//! `eps_fast` per cell. The bank now lowers the hot parameters into
//! contiguous per-bank SoA lanes (`diff_mean_s`, `diff_sigma_s`,
//! `sigma_unit_s`, `p_outlier`, `outlier_scale_s`) plus a flat lane of
//! Xoshiro256 states, and samples in three passes: a contiguous
//! branch-free Gaussian block, a rare sparse outlier pass (only
//! outlier-capable cells draw the uniform, exactly as the scalar path
//! does), and a contiguous normalization. Each cell's draw *sequence* is
//! unchanged — cell i still consumes (gaussian, [uniform, [exp, sign]])
//! from its own private state — so the block path is **bit-identical** to
//! the retained per-cell walk ([`GrngBank::fill_epsilon_legacy`], pinned
//! by `tests/grng_props.rs`), and both paths share one state lane so they
//! can be interleaved on a live bank.
//!
//! §SIMD (ISSUE 6). The per-cell states now live in a
//! [`XoshiroLanes`] SoA bank, so the Gaussian pass starts with one
//! *vertical* SIMD sweep (`crate::arch::xoshiro_block`, AVX2/NEON with a
//! scalar oracle) that draws the first uniform for every cell at once;
//! each cell then finishes its ziggurat accept/reject scalar on its own
//! lane (`rng::ziggurat_step`), and the normalization pass is a
//! dispatched elementwise divide. Because the uniform step is integer
//! and the divide is correctly rounded, the SIMD block fill stays
//! **bit-identical** to the legacy walk at every dispatch level — the
//! same property tests pin it no matter which arm runs.

use crate::config::{ChipConfig, GrngConfig};
use crate::grng::circuit::{eps_fast_step, CellParams};
use crate::grng::mismatch::DieVariation;
use crate::util::rng::{ziggurat_normal, ziggurat_step, Rng64, SplitMix64, Xoshiro256, XoshiroLanes};
use std::sync::Arc;

/// Derive the die seed for shard `shard` of a sharded serving pool.
///
/// Shard 0 keeps `die_seed` unchanged, so a single-shard pool draws the
/// exact ε stream of an unsharded bank (bit-for-bit). Higher shards get
/// independent SplitMix64-split streams — the software mirror of
/// replicating the in-word GRNG bank per compute lane (cf. VIBNN's
/// parallel RNG banks): statistically independent ε, reproducible for a
/// fixed `(die_seed, workers)` pair.
///
/// O(1): SplitMix64's state is a Weyl sequence, so the `shard`-th split
/// is reached by one [`SplitMix64::jump`] instead of looping `shard`
/// times through the splitter (bit-identical to the loop, pinned by
/// `tests/grng_props.rs`).
pub fn shard_die_seed(die_seed: u64, shard: usize) -> u64 {
    if shard == 0 {
        return die_seed;
    }
    let mut splitter = SplitMix64::new(die_seed ^ 0xD1E5_EED5_0F5A_A5F1);
    splitter.jump(shard as u64 - 1);
    splitter.split()
}

/// Chip config for shard `shard` of a serving pool: the same die family
/// with its seed split by [`shard_die_seed`]. The single home of the
/// reseed idiom, shared by [`GrngBank::for_shard`] and the coordinator's
/// `GrngBankSource::for_shard`.
pub fn shard_chip(chip: &ChipConfig, shard: usize) -> ChipConfig {
    let mut chip = chip.clone();
    chip.die_seed = shard_die_seed(chip.die_seed, shard);
    chip
}

/// Bank of GRNG cells matching a tile's σε array layout.
///
/// Cell (row, word) lives at flat index `row * words + word` in every
/// per-cell lane; [`GrngBank::fill_epsilon_planes`] additionally exposes
/// the transposed `word * rows + row` view.
#[derive(Clone)]
pub struct GrngBank {
    pub rows: usize,
    pub words: usize,
    /// Full per-cell params (AoS) — construction-time source of truth for
    /// the SoA lanes, metadata queries (offsets, energy, latency), and
    /// the retained legacy sampler. Die physics, immutable after
    /// construction: shared across MC replicas through the `Arc` (a
    /// replica clone shares the die, reseeds only its streams).
    params: Arc<Vec<CellParams>>,
    /// Per-cell sampling states in SoA lanes (state word k of every cell
    /// contiguous), shared by the block and legacy paths (interleaving
    /// them continues one stream per cell). The layout is what lets the
    /// block fill draw all cells' uniforms in one SIMD sweep.
    states: XoshiroLanes,
    /// Reused scratch for the block fill's uniform sweep (one u64 per
    /// cell; no allocation on the hot path).
    bits_scratch: Vec<u64>,
    // ---- SoA hot lanes (copies of `params` fields, row-major) ----
    // Static per die, `Arc`-shared across replica clones like `params`.
    diff_mean_s: Arc<Vec<f64>>,
    diff_sigma_s: Arc<Vec<f64>>,
    sigma_unit_s: Arc<Vec<f64>>,
    /// σ_unit lane in plane-major (`[word][row]`) order, so the
    /// plane-major normalization pass is contiguous too.
    sigma_unit_t: Arc<Vec<f64>>,
    p_outlier: Arc<Vec<f64>>,
    outlier_scale_s: Arc<Vec<f64>>,
    /// Flat indices of outlier-capable cells (p_outlier > 0) — the sparse
    /// second pass. Usually all cells (hot die) or none (p clamped to 0).
    outlier_cells: Arc<Vec<u32>>,
    /// Total samples drawn (for energy/throughput accounting).
    samples_drawn: u64,
}

impl GrngBank {
    /// Build the bank for a die.
    pub fn new(cfg: &GrngConfig, die: &DieVariation, seed: u64) -> Self {
        let n = die.rows * die.words;
        let mut seeder = SplitMix64::new(seed ^ 0x6BA4_57B1);
        let mut params = Vec::with_capacity(n);
        let mut states = XoshiroLanes::with_capacity(n);
        for i in 0..n {
            let row = i / die.words;
            let word = i % die.words;
            params.push(die.cell_params(cfg, row, word));
            states.push_seed(seeder.split());
        }
        let mut bank = Self {
            rows: die.rows,
            words: die.words,
            params: Arc::new(params),
            states,
            bits_scratch: Vec::new(),
            diff_mean_s: Arc::new(Vec::new()),
            diff_sigma_s: Arc::new(Vec::new()),
            sigma_unit_s: Arc::new(Vec::new()),
            sigma_unit_t: Arc::new(Vec::new()),
            p_outlier: Arc::new(Vec::new()),
            outlier_scale_s: Arc::new(Vec::new()),
            outlier_cells: Arc::new(Vec::new()),
            samples_drawn: 0,
        };
        bank.rebuild_lanes();
        bank
    }

    /// Lower the AoS params into the contiguous SoA sampling lanes.
    /// Construction-time only: the lanes are immutable die physics
    /// afterwards, shared by every replica through their `Arc`s.
    fn rebuild_lanes(&mut self) {
        let n = self.params.len();
        self.diff_mean_s = Arc::new(self.params.iter().map(|p| p.diff_mean_s).collect());
        self.diff_sigma_s = Arc::new(self.params.iter().map(|p| p.diff_sigma_s).collect());
        self.sigma_unit_s = Arc::new(self.params.iter().map(|p| p.sigma_unit_s).collect());
        self.p_outlier = Arc::new(self.params.iter().map(|p| p.p_outlier).collect());
        self.outlier_scale_s = Arc::new(self.params.iter().map(|p| p.outlier_scale_s).collect());
        self.outlier_cells = Arc::new(
            (0..n as u32)
                .filter(|&i| self.p_outlier[i as usize] > 0.0)
                .collect(),
        );
        let mut sigma_unit_t = vec![0.0; n];
        for r in 0..self.rows {
            for w in 0..self.words {
                sigma_unit_t[w * self.rows + r] = self.sigma_unit_s[r * self.words + w];
            }
        }
        self.sigma_unit_t = Arc::new(sigma_unit_t);
    }

    /// Convenience: bank for the configured chip with its die seed.
    pub fn for_chip(chip: &ChipConfig) -> Self {
        let die = DieVariation::draw(
            &chip.grng,
            chip.tile.rows,
            chip.tile.words_per_row,
            chip.die_seed,
        );
        Self::new(&chip.grng, &die, chip.die_seed)
    }

    /// Bank for shard `shard` of a serving pool: an independent simulated
    /// die seeded by [`shard_die_seed`]. Shard 0 is the chip's own die.
    pub fn for_shard(chip: &ChipConfig, shard: usize) -> Self {
        Self::for_chip(&shard_chip(chip, shard))
    }

    pub fn len(&self) -> usize {
        self.states.len()
    }

    pub fn is_empty(&self) -> bool {
        self.states.is_empty()
    }

    /// The derived parameters of cell (row, word).
    #[inline]
    pub fn cell_params(&self, row: usize, word: usize) -> &CellParams {
        &self.params[row * self.words + word]
    }

    /// Fill `out` (len = rows × words, row-major) with one fresh ε per
    /// cell — the parallel sampling that accompanies every MVM. Block
    /// path: contiguous Gaussian pass over the SoA lanes, sparse outlier
    /// pass, contiguous normalization. Bit-identical to
    /// [`GrngBank::fill_epsilon_legacy`].
    pub fn fill_epsilon(&mut self, out: &mut [f64]) {
        assert_eq!(out.len(), self.states.len());
        // Pass 1: one Gaussian per cell (SIMD uniform sweep + per-lane
        // ziggurat finish).
        self.fill_gaussian_block(false, out);
        // Pass 2: outlier-capable cells draw their uniform (keeping each
        // cell's sequence aligned with the scalar path); the heavy tail
        // itself is the rare branch.
        for &cell in self.outlier_cells.iter() {
            let i = cell as usize;
            let mut st = self.states.lane(i);
            if st.next_f64() < self.p_outlier[i] {
                let extra = -st.next_f64_open().ln() * self.outlier_scale_s[i];
                if st.next_bool(0.5) {
                    out[i] += extra;
                } else {
                    out[i] -= extra;
                }
            }
        }
        // Pass 3: normalize pulse widths to ε units (the same `d / σ_unit`
        // division the scalar path performs, dispatched; `_mm256_div_pd`
        // / `vdivq_f64` are correctly rounded, so still bit-identical).
        crate::arch::div_assign(out, &self.sigma_unit_s);
        self.samples_drawn += out.len() as u64;
    }

    /// Shared Gaussian pass: one SIMD sweep draws every cell's first
    /// uniform from the SoA state lanes, then each cell finishes its
    /// ziggurat accept/reject scalar on its own lane (the common case
    /// accepts the pre-drawn bits immediately; rejected cells continue
    /// their private stream exactly as the scalar sampler would).
    /// `transposed` selects row-major (`i`) vs plane-major
    /// (`(i % words) * rows + i / words`) write targets.
    fn fill_gaussian_block(&mut self, transposed: bool, out: &mut [f64]) {
        let n = self.states.len();
        let mut bits = std::mem::take(&mut self.bits_scratch);
        bits.resize(n, 0);
        self.states.fill_next_u64(&mut bits);
        let rows = self.rows;
        let words = self.words;
        for (i, &b) in bits.iter().enumerate() {
            let z = {
                let mut lane = self.states.lane(i);
                match ziggurat_step(&mut lane, b) {
                    Some(z) => z,
                    None => ziggurat_normal(&mut lane),
                }
            };
            let t = if transposed { (i % words) * rows + i / words } else { i };
            out[t] = self.diff_mean_s[i] + self.diff_sigma_s[i] * z;
        }
        self.bits_scratch = bits;
    }

    /// Fill `out` (len = rows × words) with one fresh ε per cell in the
    /// plane-major `[word][row]` layout the tile's SoA MVM fast path
    /// consumes — cell (r, w) lands at `w * rows + r`. Skips the
    /// row-major intermediate and the transpose/scatter the tile used to
    /// do. Per-cell streams are private, so the values are bit-identical
    /// to a [`GrngBank::fill_epsilon`] conversion viewed transposed
    /// (pinned by `tests/grng_props.rs`).
    pub fn fill_epsilon_planes(&mut self, out: &mut [f64]) {
        assert_eq!(out.len(), self.states.len());
        let rows = self.rows;
        let words = self.words;
        // Pass 1: SIMD uniform sweep + per-lane ziggurat finish, writes
        // transposed (the 4 KB output stays cache-resident at tile scale).
        self.fill_gaussian_block(true, out);
        // Pass 2: sparse outliers, transposed targets.
        for &cell in self.outlier_cells.iter() {
            let i = cell as usize;
            let t = (i % words) * rows + i / words;
            let mut st = self.states.lane(i);
            if st.next_f64() < self.p_outlier[i] {
                let extra = -st.next_f64_open().ln() * self.outlier_scale_s[i];
                if st.next_bool(0.5) {
                    out[t] += extra;
                } else {
                    out[t] -= extra;
                }
            }
        }
        // Pass 3: contiguous normalization against the transposed lane.
        crate::arch::div_assign(out, &self.sigma_unit_t);
        self.samples_drawn += out.len() as u64;
    }

    /// The pre-SoA reference sampler: per-cell scalar walk through the
    /// AoS params, exactly the old `Vec<GrngCell>` loop (same arithmetic
    /// via `circuit::eps_fast_step`, same per-cell states). Kept as the A/B
    /// baseline for `tests/grng_props.rs` (bit-exactness) and
    /// `benches/grng.rs` / `BENCH_grng_fill.json` (speedup).
    pub fn fill_epsilon_legacy(&mut self, out: &mut [f64]) {
        assert_eq!(out.len(), self.states.len());
        for (i, o) in out.iter_mut().enumerate() {
            let mut lane = self.states.lane(i);
            *o = eps_fast_step(&self.params[i], &mut lane);
        }
        self.samples_drawn += out.len() as u64;
    }

    /// Allocate-and-fill variant.
    pub fn epsilon_matrix(&mut self) -> Vec<f64> {
        let mut out = vec![0.0; self.states.len()];
        self.fill_epsilon(&mut out);
        out
    }

    /// True per-cell static offsets (ground truth for calibration tests).
    pub fn true_offsets(&self) -> Vec<f64> {
        self.params.iter().map(|p| p.epsilon_offset()).collect()
    }

    /// Reseed every cell's sampling stream from SplitMix64 splits of
    /// `seed`, keeping the die's physics (mismatch, energy, latency).
    /// This is how an MC-parallel replica of a calibrated tile gets an
    /// independent ε stream on the *same* die.
    pub fn reseed_cells(&mut self, seed: u64) {
        let mut seeder = SplitMix64::new(seed ^ 0x6BA4_57B1);
        for i in 0..self.states.len() {
            self.states.set(i, &Xoshiro256::new(seeder.split()));
        }
    }

    /// Mean per-sample energy across the bank \[J\]; 0.0 for an empty bank.
    pub fn mean_energy_per_sample(&self) -> f64 {
        if self.params.is_empty() {
            return 0.0;
        }
        let total: f64 = self.params.iter().map(|p| p.energy_j).sum();
        total / self.params.len() as f64
    }

    /// Mean conversion latency (≈ slowest-branch mean) across the bank
    /// \[s\]; 0.0 for an empty bank.
    pub fn mean_latency(&self) -> f64 {
        if self.params.is_empty() {
            return 0.0;
        }
        let total: f64 = self.params.iter().map(|p| p.mu_p.max(p.mu_n)).sum();
        total / self.params.len() as f64
    }

    /// Aggregate hardware sample throughput [Sa/s]: all cells convert in
    /// parallel, one sample per cell per conversion. (The paper's
    /// 5.12 GSa/s: 512 cells ÷ ~100 ns cycle.) An empty bank produces no
    /// samples: 0.0, not a panic.
    pub fn hardware_throughput_sa_s(&self) -> f64 {
        let Some(first) = self.params.first() else {
            return 0.0;
        };
        let latency = self.mean_latency() + first.cfg.dff_reset_window_s * 2.0;
        if latency <= 0.0 {
            return 0.0;
        }
        self.params.len() as f64 / latency
    }

    pub fn samples_drawn(&self) -> u64 {
        self.samples_drawn
    }

    /// Bytes of die physics behind `Arc`s (cell params + SoA lanes) —
    /// counted once per die no matter how many replicas share the bank.
    pub fn bytes_shared(&self) -> usize {
        self.params.len() * std::mem::size_of::<CellParams>()
            + (self.diff_mean_s.len()
                + self.diff_sigma_s.len()
                + self.sigma_unit_s.len()
                + self.sigma_unit_t.len()
                + self.p_outlier.len()
                + self.outlier_scale_s.len())
                * std::mem::size_of::<f64>()
            + self.outlier_cells.len() * std::mem::size_of::<u32>()
    }

    /// Bytes each replica owns privately: the Xoshiro state lanes (four
    /// u64 words per cell) plus the uniform-sweep scratch.
    pub fn bytes_private(&self) -> usize {
        self.states.len() * 4 * std::mem::size_of::<u64>()
            + self.bits_scratch.capacity() * std::mem::size_of::<u64>()
    }

    /// True when `other` shares this bank's die physics by pointer
    /// identity (replica fan-out, not an independent die).
    pub fn shares_params_with(&self, other: &GrngBank) -> bool {
        Arc::ptr_eq(&self.params, &other.params)
            && Arc::ptr_eq(&self.sigma_unit_s, &other.sigma_unit_s)
            && Arc::ptr_eq(&self.sigma_unit_t, &other.sigma_unit_t)
            && Arc::ptr_eq(&self.outlier_cells, &other.outlier_cells)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChipConfig;
    use crate::util::stats::Summary;

    #[test]
    fn bank_fills_full_matrix() {
        let chip = ChipConfig::default();
        let mut bank = GrngBank::for_chip(&chip);
        assert_eq!(bank.len(), 512);
        let eps = bank.epsilon_matrix();
        assert_eq!(eps.len(), 512);
        assert_eq!(bank.samples_drawn(), 512);
        // Not all equal (actual randomness).
        let s = Summary::from_slice(&eps);
        assert!(s.std() > 0.5);
    }

    #[test]
    fn bank_throughput_near_paper() {
        // Paper: 5.12 GSa/s from 512 parallel cells.
        let chip = ChipConfig::default();
        let bank = GrngBank::for_chip(&chip);
        let tput = bank.hardware_throughput_sa_s();
        assert!(
            (3.0e9..9.0e9).contains(&tput),
            "throughput {tput:.3e} should be in the GSa/s range"
        );
    }

    #[test]
    fn bank_energy_near_paper() {
        let chip = ChipConfig::default();
        let bank = GrngBank::for_chip(&chip);
        let e = bank.mean_energy_per_sample();
        assert!(
            (260e-15..460e-15).contains(&e),
            "energy/sample {:.0} fJ should be ≈360 fJ",
            e * 1e15
        );
    }

    #[test]
    fn different_cells_have_different_offsets() {
        let chip = ChipConfig::default();
        let bank = GrngBank::for_chip(&chip);
        let offs = bank.true_offsets();
        let s = Summary::from_slice(&offs);
        assert!(s.std() > 0.05, "mismatch must spread offsets, σ={}", s.std());
    }

    #[test]
    fn empty_bank_reports_zero_not_panic() {
        let chip = ChipConfig::default();
        let die = crate::grng::DieVariation::draw(&chip.grng, 0, 0, 1);
        let mut bank = GrngBank::new(&chip.grng, &die, 1);
        assert!(bank.is_empty());
        assert_eq!(bank.len(), 0);
        assert_eq!(bank.hardware_throughput_sa_s(), 0.0);
        assert_eq!(bank.mean_energy_per_sample(), 0.0);
        assert_eq!(bank.mean_latency(), 0.0);
        let mut out: [f64; 0] = [];
        bank.fill_epsilon(&mut out);
        bank.fill_epsilon_legacy(&mut out);
        bank.fill_epsilon_planes(&mut out);
        assert_eq!(bank.samples_drawn(), 0);
    }

    #[test]
    fn reseeded_cells_draw_new_streams_on_same_die() {
        let chip = ChipConfig::default();
        let mut a = GrngBank::for_chip(&chip);
        let mut b = GrngBank::for_chip(&chip);
        b.reseed_cells(0xD1CE);
        assert_eq!(a.true_offsets(), b.true_offsets(), "same die physics");
        let eps_b = b.epsilon_matrix();
        assert_ne!(a.epsilon_matrix(), eps_b, "new streams");
        let mut c = GrngBank::for_chip(&chip);
        c.reseed_cells(0xD1CE);
        assert_eq!(eps_b, c.epsilon_matrix(), "deterministic reseed");
    }

    #[test]
    fn shard_banks_are_independent_dies() {
        let chip = ChipConfig::default();
        assert_eq!(shard_die_seed(chip.die_seed, 0), chip.die_seed);
        let mut a = GrngBank::for_shard(&chip, 0);
        let mut b = GrngBank::for_chip(&chip);
        assert_eq!(a.epsilon_matrix(), b.epsilon_matrix());
        let mut c = GrngBank::for_shard(&chip, 1);
        let mut d = GrngBank::for_shard(&chip, 2);
        let ec = c.epsilon_matrix();
        assert_ne!(ec, d.epsilon_matrix());
        assert_ne!(ec, a.epsilon_matrix());
    }

    #[test]
    fn deterministic_per_die_seed() {
        let chip = ChipConfig::default();
        let mut a = GrngBank::for_chip(&chip);
        let mut b = GrngBank::for_chip(&chip);
        assert_eq!(a.epsilon_matrix(), b.epsilon_matrix());
        let mut chip2 = ChipConfig::default();
        chip2.die_seed = 1;
        let mut c = GrngBank::for_chip(&chip2);
        assert_ne!(a.epsilon_matrix(), c.epsilon_matrix());
    }

    #[test]
    fn block_and_legacy_paths_share_one_stream() {
        // Both samplers advance the same per-cell states, so interleaving
        // them on one bank draws the same sequence as either path alone
        // on a twin bank.
        let chip = ChipConfig::default();
        let mut mixed = GrngBank::for_chip(&chip);
        let mut pure = GrngBank::for_chip(&chip);
        let n = mixed.len();
        let mut a = vec![0.0; n];
        let mut b = vec![0.0; n];
        for round in 0..4 {
            if round % 2 == 0 {
                mixed.fill_epsilon(&mut a);
            } else {
                mixed.fill_epsilon_legacy(&mut a);
            }
            pure.fill_epsilon_legacy(&mut b);
            assert_eq!(a, b, "round {round}");
        }
        assert_eq!(mixed.samples_drawn(), pure.samples_drawn());
    }
}
