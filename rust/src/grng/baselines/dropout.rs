//! Bernoulli mask source for MC-dropout uncertainty estimation.
//!
//! [13] (Fan et al., TCAD 2022) sidesteps Gaussian sampling entirely:
//! uncertainty comes from Monte-Carlo dropout — random Bernoulli masks
//! applied at inference time. Not a Gaussian source, so it gets its own
//! type; the uncertainty benches use it as the non-Bayesian-sampling
//! comparison arm, and Tab. II quotes its published system figures.

use crate::util::rng::{Rng64, Xoshiro256};

/// Published figures of the MC-dropout FPGA design [13].
pub const MCDROPOUT_TECH_NM: f64 = 20.0;
pub const MCDROPOUT_NN_GOPS: (f64, f64) = (533.0, 1590.0);
pub const MCDROPOUT_NN_FJ_PER_OP: (f64, f64) = (24_000.0, 51_000.0);

pub struct DropoutMask {
    rng: Xoshiro256,
    /// Keep probability (1 − dropout rate).
    pub keep_p: f64,
}

impl DropoutMask {
    pub fn new(seed: u64, keep_p: f64) -> Self {
        assert!((0.0..=1.0).contains(&keep_p));
        Self {
            rng: Xoshiro256::new(seed ^ 0xD20_F0C7),
            keep_p,
        }
    }

    /// One mask value: 1/keep_p with probability keep_p else 0
    /// (inverted-dropout scaling so the expectation is 1).
    #[inline]
    pub fn sample(&mut self) -> f64 {
        if self.rng.next_f64() < self.keep_p {
            1.0 / self.keep_p
        } else {
            0.0
        }
    }

    /// Fill a mask vector for one forward pass.
    pub fn fill(&mut self, out: &mut [f32]) {
        for o in out.iter_mut() {
            *o = self.sample() as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_expectation_is_one() {
        let mut d = DropoutMask::new(3, 0.8);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| d.sample()).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.01, "inverted dropout mean {mean}");
    }

    #[test]
    fn keep_rate_respected() {
        let mut d = DropoutMask::new(4, 0.3);
        let n = 50_000;
        let kept = (0..n).filter(|_| d.sample() > 0.0).count();
        let rate = kept as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.02, "keep rate {rate}");
    }

    #[test]
    #[should_panic]
    fn invalid_keep_p_rejected() {
        let _ = DropoutMask::new(1, 1.5);
    }
}
