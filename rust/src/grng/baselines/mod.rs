//! Comparison GRNG implementations (Tab. II baselines).
//!
//! The paper compares its analog in-word GRNG against digital approaches
//! used by prior BNN accelerators. To regenerate Tab. II we implement each
//! *algorithm* and attach the published cost figures of the corresponding
//! design (their silicon is obviously not reproducible here):
//!
//! - [`hadamard`] — time-interleaved Hadamard CLT generator
//!   ([9] Dorrance et al., 22 nm ASIC).
//! - [`wallace`] — Wallace pool method ([11] VIBNN, Cyclone V FPGA;
//!   original algorithm [14] Lee et al.).
//! - [`box_muller`] — fixed-point Box–Muller ([12] Xu et al., ZU9EG FPGA).
//! - [`clt_lfsr`] — Irwin–Hall/CLT sum of LFSR uniforms (classic cheap
//!   digital GRNG; ablation baseline).
//! - [`dropout`] — Bernoulli mask source for MC-dropout
//!   ([13] Fan et al., Arria 10 FPGA), the non-Gaussian alternative.

pub mod box_muller;
pub mod clt_lfsr;
pub mod dropout;
pub mod hadamard;
pub mod wallace;

/// Cost metadata for a Gaussian source: the published figures of the
/// design that used this algorithm (for Tab. II), plus an op count that
/// lets the energy model derive a same-methodology digital estimate.
#[derive(Clone, Copy, Debug)]
pub struct SourceCost {
    /// Published energy per sample [pJ/Sa] (None if not reported).
    pub published_pj_per_sa: Option<f64>,
    /// Published throughput [GSa/s].
    pub published_gsa_s: Option<f64>,
    /// Published area [mm²] (ASICs only).
    pub published_area_mm2: Option<f64>,
    /// Technology node of the published design \[nm\].
    pub tech_nm: f64,
    /// Approximate digital op count per sample (for our own estimate).
    pub ops_per_sample: f64,
}

/// A stream of (approximately) standard-normal samples.
pub trait GaussianSource {
    fn name(&self) -> &'static str;
    fn sample(&mut self) -> f64;
    fn cost(&self) -> SourceCost;

    fn sample_n(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.sample()).collect()
    }
}

/// All comparison sources with a common seed (for the comparison bench).
pub fn all_sources(seed: u64) -> Vec<Box<dyn GaussianSource>> {
    vec![
        Box::new(hadamard::TiHadamard::new(seed)),
        Box::new(wallace::Wallace::new(seed)),
        Box::new(box_muller::FixedPointBoxMuller::new(seed)),
        Box::new(clt_lfsr::CltLfsr::new(seed)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{qq_r_value, Summary};

    #[test]
    fn all_sources_are_roughly_standard_normal() {
        for mut src in all_sources(0xA11CE) {
            let xs = src.sample_n(20_000);
            let s = Summary::from_slice(&xs);
            assert!(
                s.mean().abs() < 0.04,
                "{}: mean {}",
                src.name(),
                s.mean()
            );
            assert!(
                (s.std() - 1.0).abs() < 0.06,
                "{}: std {}",
                src.name(),
                s.std()
            );
            let r = qq_r_value(&xs[..2500.min(xs.len())]);
            assert!(r > 0.97, "{}: qq r {}", src.name(), r);
        }
    }

    #[test]
    fn costs_present_for_published_designs() {
        let srcs = all_sources(1);
        let hadamard = &srcs[0];
        assert!(hadamard.cost().published_pj_per_sa.is_some());
        assert!(hadamard.cost().ops_per_sample > 0.0);
    }
}
