//! Fixed-point Box–Muller Gaussian generator.
//!
//! Models the GRNG of [12] (Xu et al., OJCAS 2021): the classic
//! Box–Muller transform implemented with fixed-point arithmetic and
//! table-based ln/√/cos — we emulate the dominant hardware artifact
//! (quantization of the uniforms and the output to INT16-scale grids)
//! on top of the exact transform.

use super::{GaussianSource, SourceCost};
use crate::util::rng::{Rng64, Xoshiro256};

/// Output fixed-point scale: Q4.12-ish (matches [12]'s INT16 datapath).
const OUT_SCALE: f64 = 4096.0;
/// Uniform input resolution (16-bit fraction).
const U_SCALE: f64 = 65536.0;

pub struct FixedPointBoxMuller {
    rng: Xoshiro256,
    spare: Option<f64>,
}

impl FixedPointBoxMuller {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Xoshiro256::new(seed ^ 0xB0C5_0411),
            spare: None,
        }
    }

    fn quantize_unit(u: f64) -> f64 {
        // 16-bit uniform, open interval (0,1] so ln is finite.
        ((u * U_SCALE).floor() + 1.0) / U_SCALE
    }

    fn quantize_out(x: f64) -> f64 {
        (x * OUT_SCALE).round() / OUT_SCALE
    }
}

impl GaussianSource for FixedPointBoxMuller {
    fn name(&self) -> &'static str {
        "box-muller [12]"
    }

    fn sample(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        let u1 = Self::quantize_unit(self.rng.next_f64());
        let u2 = Self::quantize_unit(self.rng.next_f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        let z0 = Self::quantize_out(r * theta.cos());
        let z1 = Self::quantize_out(r * theta.sin());
        self.spare = Some(z1);
        z0
    }

    fn cost(&self) -> SourceCost {
        SourceCost {
            // [12]: 5.40 pJ/Sa, 8.88 GSa/s on ZU9EG (16 nm).
            published_pj_per_sa: Some(5.40),
            published_gsa_s: Some(8.88),
            published_area_mm2: None,
            tech_nm: 16.0,
            // 2 table lookups + mult + trig approx ≈ 12 ops / 2 samples.
            ops_per_sample: 6.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{qq_r_value, Summary};

    #[test]
    fn spare_sample_used() {
        let mut g = FixedPointBoxMuller::new(1);
        let _ = g.sample();
        assert!(g.spare.is_some());
        let _ = g.sample();
        assert!(g.spare.is_none());
    }

    #[test]
    fn quantization_grid() {
        let mut g = FixedPointBoxMuller::new(2);
        for _ in 0..100 {
            let v = g.sample();
            let on_grid = (v * OUT_SCALE).round() / OUT_SCALE;
            assert!((v - on_grid).abs() < 1e-12, "output {v} not on grid");
        }
    }

    #[test]
    fn tail_not_truncated_badly() {
        // 16-bit u1 bounds |z| ≤ √(−2·ln(1/65536)) ≈ 4.71.
        let mut g = FixedPointBoxMuller::new(3);
        let xs = g.sample_n(200_000);
        let max = xs.iter().cloned().fold(0.0f64, |a, b| a.max(b.abs()));
        assert!(max < 4.8);
        assert!(max > 3.5, "should still reach the tails, max={max}");
        let s = Summary::from_slice(&xs);
        assert!((s.std() - 1.0).abs() < 0.02);
        assert!(qq_r_value(&xs[..2500]) > 0.99);
    }
}
