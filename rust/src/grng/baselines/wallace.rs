//! Wallace-method Gaussian generator.
//!
//! Models the GRNG of [11] (VIBNN, ASPLOS'18), which uses the Wallace
//! method [14] (Lee et al., TVLSI 2005): maintain a pool of Gaussian
//! variates; each step applies a random orthogonal transform to a small
//! group, producing new Gaussians *without* evaluating transcendental
//! functions (the appeal for FPGA/ASIC implementation). Orthogonality
//! preserves the pool's sum-of-squares, so outputs stay Gaussian; a
//! slow chi-square-driven rescale corrects residual drift.

use super::{GaussianSource, SourceCost};
use crate::util::rng::{ziggurat_normal, Rng64, Xoshiro256};

const POOL: usize = 1024;
const GROUP: usize = 4;
/// Rescale cadence (pool passes between variance corrections).
const RESCALE_EVERY: usize = 8 * POOL;

pub struct Wallace {
    rng: Xoshiro256,
    pool: Vec<f64>,
    emitted: usize,
    since_rescale: usize,
}

impl Wallace {
    pub fn new(seed: u64) -> Self {
        let mut rng = Xoshiro256::new(seed ^ 0x3A11A5E);
        // Initialize the pool from a reference sampler (hardware does this
        // once at boot from a ROM of Gaussian constants).
        let pool = (0..POOL).map(|_| ziggurat_normal(&mut rng)).collect();
        Self {
            rng,
            pool,
            emitted: 0,
            since_rescale: 0,
        }
    }

    /// 4×4 orthogonal transform (normalized Hadamard H₄/2): maps 4
    /// Gaussians to 4 fresh Gaussians with the same total energy.
    #[inline]
    fn transform(vals: [f64; GROUP]) -> [f64; GROUP] {
        let [a, b, c, d] = vals;
        [
            0.5 * (a + b + c + d),
            0.5 * (a - b + c - d),
            0.5 * (a + b - c - d),
            0.5 * (a - b - c + d),
        ]
    }

    fn step(&mut self) {
        // Pick 4 distinct-ish random slots (collisions are harmless: the
        // transform is still orthogonal over the distinct subset in
        // expectation; hardware uses strided addressing).
        let i0 = self.rng.next_below(POOL as u64) as usize;
        let i1 = self.rng.next_below(POOL as u64) as usize;
        let i2 = self.rng.next_below(POOL as u64) as usize;
        let i3 = self.rng.next_below(POOL as u64) as usize;
        let vals = [self.pool[i0], self.pool[i1], self.pool[i2], self.pool[i3]];
        let out = Self::transform(vals);
        self.pool[i0] = out[0];
        self.pool[i1] = out[1];
        self.pool[i2] = out[2];
        self.pool[i3] = out[3];
        self.since_rescale += GROUP;
        if self.since_rescale >= RESCALE_EVERY {
            self.rescale();
        }
    }

    /// Variance correction: renormalize pool energy to POOL (a hardware
    /// Wallace generator multiplies by a χ-distributed correction factor).
    fn rescale(&mut self) {
        let energy: f64 = self.pool.iter().map(|x| x * x).sum();
        let k = (POOL as f64 / energy).sqrt();
        for v in self.pool.iter_mut() {
            *v *= k;
        }
        self.since_rescale = 0;
    }
}

impl GaussianSource for Wallace {
    fn name(&self) -> &'static str {
        "wallace [11]"
    }

    fn sample(&mut self) -> f64 {
        self.step();
        let idx = self.emitted % POOL;
        self.emitted += 1;
        self.pool[idx]
    }

    fn cost(&self) -> SourceCost {
        SourceCost {
            // [11] VIBNN: 38.8 pJ/Sa, 13.63 GSa/s on Cyclone V (28 nm).
            published_pj_per_sa: Some(38.8),
            published_gsa_s: Some(13.63),
            published_area_mm2: None,
            tech_nm: 28.0,
            // 4 reads + 8 add/sub + 4 writes per 4 outputs + addressing.
            ops_per_sample: 5.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{qq_r_value, Summary};

    #[test]
    fn transform_is_orthogonal() {
        let v = [1.0, -2.0, 3.0, 0.5];
        let o = Wallace::transform(v);
        let e_in: f64 = v.iter().map(|x| x * x).sum();
        let e_out: f64 = o.iter().map(|x| x * x).sum();
        assert!((e_in - e_out).abs() < 1e-12, "energy must be preserved");
    }

    #[test]
    fn pool_energy_stays_bounded() {
        let mut w = Wallace::new(3);
        let _ = w.sample_n(50_000);
        let energy: f64 = w.pool.iter().map(|x| x * x).sum();
        let per_slot = energy / POOL as f64;
        assert!(
            (0.7..1.4).contains(&per_slot),
            "pool variance drifted to {per_slot}"
        );
    }

    #[test]
    fn long_run_normality() {
        let mut w = Wallace::new(8);
        // Skip warmup (initial pool correlations).
        let _ = w.sample_n(10_000);
        let xs = w.sample_n(2500);
        let s = Summary::from_slice(&xs);
        assert!(s.mean().abs() < 0.08);
        assert!((s.std() - 1.0).abs() < 0.08);
        assert!(qq_r_value(&xs) > 0.995);
    }
}
