//! Time-interleaved Hadamard CLT Gaussian generator.
//!
//! Models the digital GRNG of [9] (Dorrance et al., JSSC 2023): a block of
//! uniform ±1 bits is passed through a fast Walsh–Hadamard transform;
//! each output coordinate is a sum of N independent ±1 terms, so by the
//! CLT it is approximately N(0, N) — normalized by √N. "Time-interleaved"
//! refers to producing the transform outputs over successive cycles from
//! one bit-block while the next block streams in; here that manifests as
//! a buffered block generator.

use super::{GaussianSource, SourceCost};
use crate::util::rng::{Rng64, Xoshiro256};

/// Block size (order of the Hadamard matrix). [9] uses small orders
/// time-interleaved; 64 balances normality vs cost.
const ORDER: usize = 64;

pub struct TiHadamard {
    rng: Xoshiro256,
    buf: [f64; ORDER],
    pos: usize,
}

impl TiHadamard {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Xoshiro256::new(seed ^ 0x44AD_0ADA),
            buf: [0.0; ORDER],
            pos: ORDER, // force refill on first sample
        }
    }

    /// In-place fast Walsh–Hadamard transform (unnormalized).
    fn fwht(data: &mut [f64; ORDER]) {
        let mut h = 1;
        while h < ORDER {
            let mut i = 0;
            while i < ORDER {
                for j in i..i + h {
                    let x = data[j];
                    let y = data[j + h];
                    data[j] = x + y;
                    data[j + h] = x - y;
                }
                i += 2 * h;
            }
            h *= 2;
        }
    }

    fn refill(&mut self) {
        // Draw 64 random ±1 values from one 64-bit word.
        let bits = self.rng.next_u64();
        for (i, slot) in self.buf.iter_mut().enumerate() {
            *slot = if (bits >> i) & 1 == 1 { 1.0 } else { -1.0 };
        }
        Self::fwht(&mut self.buf);
        let norm = 1.0 / (ORDER as f64).sqrt();
        for slot in self.buf.iter_mut() {
            *slot *= norm;
        }
        self.pos = 0;
    }
}

impl GaussianSource for TiHadamard {
    fn name(&self) -> &'static str {
        "ti-hadamard [9]"
    }

    fn sample(&mut self) -> f64 {
        if self.pos >= ORDER {
            self.refill();
        }
        let v = self.buf[self.pos];
        self.pos += 1;
        v
    }

    fn cost(&self) -> SourceCost {
        SourceCost {
            // [9]: 1.08–1.69 pJ/Sa, 4.65–7.31 GSa/s, 3.88 mm², 22 nm.
            published_pj_per_sa: Some(1.08),
            published_gsa_s: Some(4.65),
            published_area_mm2: Some(3.88),
            tech_nm: 22.0,
            // FWHT: N·log2 N adds per N outputs → log2 N adds/sample + RNG.
            ops_per_sample: 6.0 + 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    #[test]
    fn hadamard_transform_orthogonality() {
        // FWHT of a delta is a constant row of ±1 — check Parseval.
        let mut data = [0.0; ORDER];
        data[3] = 1.0;
        TiHadamard::fwht(&mut data);
        let energy: f64 = data.iter().map(|x| x * x).sum();
        assert!((energy - ORDER as f64).abs() < 1e-9);
        for &v in &data {
            assert!((v.abs() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn outputs_within_clt_range() {
        // Each output is a sum of 64 ±1 / 8 → |x| ≤ 8.
        let mut g = TiHadamard::new(5);
        for _ in 0..10_000 {
            let v = g.sample();
            assert!(v.abs() <= 8.0 + 1e-12);
        }
    }

    #[test]
    fn block_samples_are_uncorrelated() {
        let mut g = TiHadamard::new(9);
        let xs = g.sample_n(ORDER * 200);
        // Correlation between successive outputs within blocks.
        let mut num = 0.0;
        let mut den = 0.0;
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        for i in 0..xs.len() - 1 {
            num += (xs[i] - m) * (xs[i + 1] - m);
            den += (xs[i] - m) * (xs[i] - m);
        }
        assert!((num / den).abs() < 0.05);
        let s = Summary::from_slice(&xs);
        assert!((s.std() - 1.0).abs() < 0.05);
    }
}
