//! CLT (Irwin–Hall) Gaussian generator over a hardware-style LFSR.
//!
//! The cheapest classical digital GRNG: sum 12 uniform U(0,1) variates and
//! subtract 6 — mean 0, variance 1 by construction, approximately normal
//! by the CLT (the classic "RAND12" trick). Uniforms come from a Galois
//! LFSR, the canonical hardware uniform source. Included as the ablation
//! floor for GRNG quality-vs-cost comparisons: its tails are hard-clipped
//! at ±6, which measurably hurts BNN uncertainty tails.

use super::{GaussianSource, SourceCost};

/// 32-bit Galois LFSR with maximal-length taps (0xA3000000 ↔ x³²+x³⁰+x²⁶+x²⁵+1).
pub struct Lfsr32 {
    state: u32,
}

impl Lfsr32 {
    pub fn new(seed: u32) -> Self {
        Self {
            state: if seed == 0 { 0xDEADBEEF } else { seed },
        }
    }

    #[inline]
    pub fn next_bit(&mut self) -> u32 {
        let lsb = self.state & 1;
        self.state >>= 1;
        if lsb == 1 {
            self.state ^= 0xA300_0000;
        }
        lsb
    }

    /// Next 16 bits as a uniform in [0, 1).
    #[inline]
    pub fn next_unit16(&mut self) -> f64 {
        let mut v = 0u32;
        for _ in 0..16 {
            v = (v << 1) | self.next_bit();
        }
        v as f64 / 65536.0
    }
}

pub struct CltLfsr {
    lfsr: Lfsr32,
}

impl CltLfsr {
    pub fn new(seed: u64) -> Self {
        Self {
            lfsr: Lfsr32::new((seed as u32) ^ 0xC17_F5F1),
        }
    }
}

impl GaussianSource for CltLfsr {
    fn name(&self) -> &'static str {
        "clt-lfsr (ablation)"
    }

    fn sample(&mut self) -> f64 {
        let mut acc = 0.0;
        for _ in 0..12 {
            acc += self.lfsr.next_unit16();
        }
        acc - 6.0
    }

    fn cost(&self) -> SourceCost {
        SourceCost {
            published_pj_per_sa: None,
            published_gsa_s: None,
            published_area_mm2: None,
            tech_nm: 65.0,
            // 12 × 16-bit LFSR shifts + 12 adds.
            ops_per_sample: 24.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    #[test]
    fn lfsr_period_is_long() {
        // State must not return to seed quickly (maximal-length check, abbreviated).
        let mut l = Lfsr32::new(1);
        let start = l.state;
        for _ in 0..100_000 {
            l.next_bit();
            assert_ne!(l.state, 0, "LFSR must never hit the all-zero state");
        }
        assert_ne!(l.state, start);
    }

    #[test]
    fn clt_variance_by_construction() {
        let mut g = CltLfsr::new(77);
        let xs = g.sample_n(50_000);
        let s = Summary::from_slice(&xs);
        assert!(s.mean().abs() < 0.02, "mean {}", s.mean());
        assert!((s.std() - 1.0).abs() < 0.02, "std {}", s.std());
    }

    #[test]
    fn tails_clipped_at_six() {
        let mut g = CltLfsr::new(78);
        for _ in 0..100_000 {
            let v = g.sample();
            assert!(v.abs() <= 6.0);
        }
    }
}
