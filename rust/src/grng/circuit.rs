//! Behavioral circuit simulation of the in-word GRNG cell (Fig. 4).
//!
//! Two modes share one parameter derivation ([`CellParams`]):
//!
//! - [`GrngCell::sample_circuit`] — full stochastic transient: Euler–
//!   Maruyama integration of both capacitor discharges with per-step shot
//!   noise, per-conversion low-frequency (RTN/flicker) slope error, kTC
//!   initial-voltage noise, threshold-crossing interpolation, and outlier
//!   injection (DFF mis-reset bursts). This is the *characterization*
//!   path used by Fig. 8/9 and Tab. I benches.
//! - [`GrngCell::sample_fast`] — closed-form draw from the same physics
//!   (crossing times are Gaussian to first order), used on the MVM hot
//!   path where millions of ε are needed. A unit test pins the two modes
//!   to agree in distribution.

use crate::config::GrngConfig;
use crate::grng::physics;
use crate::util::rng::{Rng64, Xoshiro256};

/// Static per-cell parameters derived from config + die mismatch.
#[derive(Clone, Debug)]
pub struct CellParams {
    pub cfg: GrngConfig,
    /// Per-branch threshold-voltage mismatch \[V\] (static, per die).
    pub dvth_p: f64,
    pub dvth_n: f64,
    /// Derived: per-branch leakage currents \[A\].
    pub i_p: f64,
    pub i_n: f64,
    /// Derived: per-branch mean crossing times \[s\].
    pub mu_p: f64,
    pub mu_n: f64,
    /// Derived: per-branch crossing σ \[s\].
    pub sigma_p: f64,
    pub sigma_n: f64,
    /// Outlier probability per sample.
    pub p_outlier: f64,
    /// Outlier mean magnitude \[s\].
    pub outlier_scale_s: f64,
    /// ε normalization unit \[s\].
    pub sigma_unit_s: f64,
    /// Energy per sample \[J\].
    pub energy_j: f64,
    /// Precomputed pulse-width mean μ_n − μ_p \[s\] (hot-path).
    pub diff_mean_s: f64,
    /// Precomputed pulse-width σ = √(σ_p² + σ_n²) \[s\] (hot-path).
    pub diff_sigma_s: f64,
}

impl CellParams {
    /// Derive cell parameters at the config's operating point with the
    /// given static mismatch.
    pub fn derive(cfg: &GrngConfig, dvth_p: f64, dvth_n: f64) -> CellParams {
        let temp_k = cfg.temp_k();
        let i_p = physics::leakage_current(cfg, cfg.bias_v, temp_k, dvth_p);
        let i_n = physics::leakage_current(cfg, cfg.bias_v, temp_k, dvth_n);
        let mu_p = physics::mean_crossing_time(cfg, i_p);
        let mu_n = physics::mean_crossing_time(cfg, i_n);
        let sigma_p = physics::total_sigma(cfg, temp_k, mu_p, i_p);
        let sigma_n = physics::total_sigma(cfg, temp_k, mu_n, i_n);
        // Nominal (mismatch-free) operating point for normalization.
        let op = physics::operating_point(cfg, cfg.bias_v, cfg.temp_c);
        let sigma_unit_s = if cfg.sigma_unit_s > 0.0 {
            cfg.sigma_unit_s
        } else {
            op.pulse_sigma
        };
        CellParams {
            cfg: cfg.clone(),
            dvth_p,
            dvth_n,
            i_p,
            i_n,
            mu_p,
            mu_n,
            sigma_p,
            sigma_n,
            diff_mean_s: mu_n - mu_p,
            diff_sigma_s: (sigma_p * sigma_p + sigma_n * sigma_n).sqrt(),
            p_outlier: physics::outlier_probability(cfg, temp_k),
            outlier_scale_s: cfg.outlier_magnitude
                * physics::outlier_magnitude_scale(cfg, temp_k)
                * op.pulse_sigma,
            // NOTE: outliers corrupt the *pulse width* (spurious E edges
            // from a DFF mis-reset), not the conversion latency — Tab. I
            // shows latency falling monotonically with temperature even
            // as normality collapses.
            sigma_unit_s,
            energy_j: physics::energy_per_sample(cfg, 0.5 * (i_p + i_n)),
        }
    }

    /// Static offset ε₀ of this cell (Eq. 8), in ε units: the mean of the
    /// output distribution caused by branch mismatch.
    pub fn epsilon_offset(&self) -> f64 {
        (self.mu_n - self.mu_p) / self.sigma_unit_s
    }
}

/// One GRNG output sample.
#[derive(Clone, Copy, Debug)]
pub struct GrngSample {
    /// Signed time-domain value (t_n − t_p) \[s\]; the pulse width is its
    /// magnitude, the sign selects BL_P vs BL_N steering (§III-D).
    pub signed_width_s: f64,
    /// Normalized ε = signed_width / σ_unit.
    pub eps: f64,
    /// Conversion latency (both branches crossed) \[s\].
    pub latency_s: f64,
    /// Energy consumed \[J\].
    pub energy_j: f64,
    /// Whether an outlier event (trap burst / DFF mis-reset) occurred.
    pub outlier: bool,
}

/// A single in-word GRNG cell.
#[derive(Clone, Debug)]
pub struct GrngCell {
    pub params: CellParams,
    rng: Xoshiro256,
}

impl GrngCell {
    pub fn new(params: CellParams, seed: u64) -> Self {
        Self {
            params,
            rng: Xoshiro256::new(seed),
        }
    }

    /// Ideal (mismatch-free) cell from a config.
    pub fn ideal(cfg: &GrngConfig, seed: u64) -> Self {
        Self::new(CellParams::derive(cfg, 0.0, 0.0), seed)
    }

    /// Replace the sampling stream, keeping the cell's physics (mismatch,
    /// energy, latency). Used to split ε streams for MC-parallel replicas
    /// of the same die.
    pub fn reseed(&mut self, seed: u64) {
        self.rng = Xoshiro256::new(seed);
    }

    // -------------------------------------------------------------------
    // Full transient simulation
    // -------------------------------------------------------------------

    /// Simulate one complete conversion with the stochastic ODE.
    pub fn sample_circuit(&mut self) -> GrngSample {
        // Copy out the four branch scalars instead of cloning the whole
        // CellParams (which embeds a full GrngConfig) per conversion.
        let (i_p, mu_p) = (self.params.i_p, self.params.mu_p);
        let (i_n, mu_n) = (self.params.i_n, self.params.mu_n);
        let t_p = self.simulate_branch(i_p, mu_p);
        let t_n = self.simulate_branch(i_n, mu_n);
        self.finish_sample(t_p, t_n)
    }

    /// Integrate one branch: dV = −(I·m_lf + i_shot(t))·dt/C from
    /// V₀ = V_DD + kTC noise down to V_Thr. Returns the crossing time.
    fn simulate_branch(&mut self, i_leak: f64, mu_t: f64) -> f64 {
        let cfg = &self.params.cfg;
        let temp_k = cfg.temp_k();
        let c = cfg.cap_f;
        let dt = mu_t * cfg.sim_dt_frac;
        // Per-conversion low-frequency slope error (RTN/flicker): the
        // closed-form σ_rtn is realized as a quasi-static current error.
        let rel_lf = physics::rtn_sigma(cfg, temp_k, mu_t) / mu_t;
        let m_lf = 1.0 + rel_lf * self.rng.next_gaussian();
        // Shot noise: white current noise whose diffusion reproduces
        // Eq. 7 exactly: σ_T² = μ_T·q·κ/(2I) requires S_I = q·I·κ/2
        // (the single-sided/double-sided PSD convention is folded into κ).
        let sigma_i_step = (0.5 * physics::Q_E * i_leak * cfg.noise_scale / dt).sqrt();
        // kTC: sampled initial voltage.
        let v0 = cfg.vdd + (physics::K_B * temp_k / c).sqrt() * self.rng.next_gaussian();
        let mut v = v0;
        let mut t = 0.0;
        let i_mean = i_leak * m_lf;
        loop {
            let i_inst = i_mean + sigma_i_step * self.rng.next_gaussian();
            let v_next = v - i_inst * dt / c;
            if v_next <= cfg.v_thr {
                // Linear interpolation of the crossing instant inside the step.
                let frac = (v - cfg.v_thr) / (v - v_next);
                return t + frac * dt;
            }
            v = v_next;
            t += dt;
            // Safety: never integrate more than 20 mean crossings (an
            // extreme downward noise excursion cannot stall the sim).
            if t > 20.0 * mu_t {
                return t;
            }
        }
    }

    // -------------------------------------------------------------------
    // Fast closed-form sampling
    // -------------------------------------------------------------------

    /// Draw one sample from the closed-form crossing-time distributions.
    pub fn sample_fast(&mut self) -> GrngSample {
        let p = &self.params;
        let t_p = p.mu_p + p.sigma_p * self.rng.next_gaussian();
        let t_n = p.mu_n + p.sigma_n * self.rng.next_gaussian();
        self.finish_sample(t_p, t_n)
    }

    /// Fast path returning only ε (no bookkeeping) — the MVM hot loop.
    /// Delegates to `eps_fast_step`, the shared sampling arithmetic.
    #[inline]
    pub fn eps_fast(&mut self) -> f64 {
        eps_fast_step(&self.params, &mut self.rng)
    }

    fn finish_sample(&mut self, t_p: f64, t_n: f64) -> GrngSample {
        let p = &self.params;
        // Outlier: a DFF mis-reset emits a spurious E edge, corrupting the
        // measured pulse width; the conversion latency (reset of both
        // branches) is unaffected (Tab. I: latency falls with T even as
        // normality collapses).
        let outlier = self.rng.next_f64() < p.p_outlier;
        let mut signed = t_n - t_p;
        if outlier {
            let extra = -self.rng.next_f64_open().ln() * p.outlier_scale_s;
            signed += if self.rng.next_bool(0.5) { extra } else { -extra };
        }
        GrngSample {
            signed_width_s: signed,
            eps: signed / p.sigma_unit_s,
            latency_s: t_p.max(t_n),
            energy_j: p.energy_j,
            outlier,
        }
    }

    /// Batch characterization: n circuit-level samples.
    pub fn characterize(&mut self, n: usize) -> Vec<GrngSample> {
        let mut out = Vec::new();
        self.characterize_into(n, &mut out);
        out
    }

    /// Into-buffer characterization: reuses `out`'s allocation, so sweep
    /// drivers (Fig. 8/9, Tab. I, the `grng` bench) draw millions of
    /// samples without a fresh `Vec<GrngSample>` per point.
    pub fn characterize_into(&mut self, n: usize, out: &mut Vec<GrngSample>) {
        out.clear();
        out.reserve(n);
        for _ in 0..n {
            out.push(self.sample_circuit());
        }
    }

    /// Into-buffer fast sampling (closed-form mode of the same sweeps).
    pub fn sample_fast_into(&mut self, n: usize, out: &mut Vec<GrngSample>) {
        out.clear();
        out.reserve(n);
        for _ in 0..n {
            out.push(self.sample_fast());
        }
    }
}

/// One ε draw from precomputed cell params and an explicit RNG state —
/// the single source of the hot-path sampling arithmetic, shared by
/// [`GrngCell::eps_fast`] and [`crate::grng::GrngBank`]'s retained
/// per-cell legacy sampler (so the two can never drift apart).
///
/// §Perf: t_n − t_p of two independent Gaussians IS a Gaussian with
/// precomputed (diff_mean, diff_sigma), so one draw replaces two
/// (distribution unchanged; verified by `eps_is_approximately_
/// standard_normal` and the circuit-vs-fast pinning test). Outliers
/// are the rare path: skip the uniform draw entirely when p = 0.
/// Generic over [`Rng64`] so the bank's SoA state lanes can feed a
/// borrowed per-lane view (`XoshiroLane`) through the same arithmetic.
#[inline]
pub(crate) fn eps_fast_step<R: Rng64>(p: &CellParams, rng: &mut R) -> f64 {
    let mut d = p.diff_mean_s + p.diff_sigma_s * rng.next_gaussian();
    if p.p_outlier > 0.0 && rng.next_f64() < p.p_outlier {
        let extra = -rng.next_f64_open().ln() * p.outlier_scale_s;
        if rng.next_bool(0.5) {
            d += extra;
        } else {
            d -= extra;
        }
    }
    d / p.sigma_unit_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{self, Summary};

    fn default_cell(seed: u64) -> GrngCell {
        GrngCell::ideal(&GrngConfig::default(), seed)
    }

    #[test]
    fn circuit_sample_basic_properties() {
        let mut cell = default_cell(1);
        let s = cell.sample_circuit();
        assert!(s.latency_s > 0.0);
        assert!(s.energy_j > 0.0);
        assert!(s.eps.abs() < 50.0);
    }

    #[test]
    fn circuit_mean_latency_matches_closed_form() {
        let mut cell = default_cell(2);
        let n = 400;
        let samples = cell.characterize(n);
        let mut lat = Summary::new();
        for s in &samples {
            lat.push(s.latency_s);
        }
        // E[max of two ~equal gaussians] ≈ μ_T + σ/√π — dominated by μ_T.
        let mu_t = cell.params.mu_p;
        assert!(
            (lat.mean() - mu_t).abs() < 0.05 * mu_t,
            "latency {:.3e} vs μ_T {:.3e}",
            lat.mean(),
            mu_t
        );
    }

    #[test]
    fn circuit_and_fast_agree_in_distribution() {
        let mut cell_a = default_cell(3);
        let mut cell_b = default_cell(4);
        let n = 1200;
        let eps_circ: Vec<f64> = (0..n).map(|_| cell_a.sample_circuit().eps).collect();
        let eps_fast: Vec<f64> = (0..n).map(|_| cell_b.sample_fast().eps).collect();
        let sc = Summary::from_slice(&eps_circ);
        let sf = Summary::from_slice(&eps_fast);
        assert!(sc.mean().abs() < 0.12, "circuit mean {}", sc.mean());
        assert!(sf.mean().abs() < 0.12, "fast mean {}", sf.mean());
        let ratio = sc.std() / sf.std();
        assert!(
            (0.85..1.18).contains(&ratio),
            "σ ratio circuit/fast = {ratio:.3} (circ {:.3}, fast {:.3})",
            sc.std(),
            sf.std()
        );
    }

    #[test]
    fn eps_is_approximately_standard_normal() {
        // The auto-calibrated σ_unit should make ε ~ N(0,1).
        let mut cell = default_cell(5);
        let eps: Vec<f64> = (0..4000).map(|_| cell.eps_fast()).collect();
        let s = Summary::from_slice(&eps);
        assert!(s.mean().abs() < 0.06, "mean {}", s.mean());
        assert!((s.std() - 1.0).abs() < 0.08, "std {}", s.std());
        let r = stats::qq_r_value(&eps);
        assert!(r > 0.99, "qq r {r}");
    }

    #[test]
    fn mismatch_shifts_mean() {
        let cfg = GrngConfig::default();
        // Slower N-branch (positive ΔVth) → t_n later → positive ε₀.
        let params = CellParams::derive(&cfg, 0.0, 0.01);
        assert!(params.epsilon_offset() > 0.5);
        let mut cell = GrngCell::new(params, 6);
        let eps: Vec<f64> = (0..2000).map(|_| cell.sample_fast().eps).collect();
        let m = stats::mean(&eps);
        let expect = cell.params.epsilon_offset();
        assert!(
            (m - expect).abs() < 0.15 * expect.abs().max(1.0),
            "measured offset {m:.3} vs predicted {expect:.3}"
        );
    }

    #[test]
    fn hot_cell_produces_outliers() {
        let mut cfg = GrngConfig::default();
        cfg.temp_c = 60.0;
        let mut cell = GrngCell::ideal(&cfg, 7);
        let n = 3000;
        let outliers = (0..n).filter(|_| cell.sample_fast().outlier).count();
        let p = physics::outlier_probability(&cfg, cfg.temp_k());
        let expect = p * n as f64;
        assert!(
            (outliers as f64) > 0.4 * expect,
            "outliers {outliers} vs expected ≈{expect:.0}"
        );
    }
}
