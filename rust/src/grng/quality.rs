//! Normality / quality battery for GRNG output distributions.
//!
//! Bundles the statistics the paper reports (Q–Q r-value, pulse-width σ,
//! latency) with additional tests (KS, Jarque–Bera, lag-1 autocorrelation)
//! into one report used by the `grng` bench and the `grng-char` CLI.

use crate::grng::circuit::GrngSample;
use crate::util::stats::{self, Summary};

/// Quality report for a batch of GRNG samples.
#[derive(Clone, Debug)]
pub struct QualityReport {
    pub n: usize,
    /// Pulse-width (signed) mean \[s\] — ≈0 for a calibrated cell.
    pub mean_width_s: f64,
    /// Pulse-width standard deviation \[s\] (paper Fig. 8 / Tab. I "T_D SD").
    pub width_sd_s: f64,
    /// Mean conversion latency \[s\].
    pub mean_latency_s: f64,
    /// Q–Q normal-probability-plot r-value (paper's normality metric).
    pub qq_r: f64,
    /// KS statistic against N(mean, sd).
    pub ks_d: f64,
    /// KS p-value.
    pub ks_p: f64,
    /// Jarque–Bera statistic.
    pub jarque_bera: f64,
    /// Lag-1 autocorrelation of the ε sequence (should be ≈0: each
    /// conversion is physically independent).
    pub lag1_autocorr: f64,
    /// Mean energy per sample \[J\].
    pub mean_energy_j: f64,
    /// Fraction of outlier samples.
    pub outlier_frac: f64,
}

impl QualityReport {
    pub fn from_samples(samples: &[GrngSample]) -> Self {
        assert!(samples.len() >= 8, "need a reasonable batch");
        let widths: Vec<f64> = samples.iter().map(|s| s.signed_width_s).collect();
        let lats: Vec<f64> = samples.iter().map(|s| s.latency_s).collect();
        let eps: Vec<f64> = samples.iter().map(|s| s.eps).collect();
        let sw = Summary::from_slice(&widths);
        let sl = Summary::from_slice(&lats);
        let ks_d = stats::ks_statistic_normal(&widths, sw.mean(), sw.sample_std());
        Self {
            n: samples.len(),
            mean_width_s: sw.mean(),
            width_sd_s: sw.sample_std(),
            mean_latency_s: sl.mean(),
            qq_r: stats::qq_r_value(&widths),
            ks_d,
            ks_p: stats::ks_p_value(ks_d, samples.len()),
            jarque_bera: stats::jarque_bera(&widths),
            lag1_autocorr: lag1(&eps),
            mean_energy_j: samples.iter().map(|s| s.energy_j).sum::<f64>()
                / samples.len() as f64,
            outlier_frac: samples.iter().filter(|s| s.outlier).count() as f64
                / samples.len() as f64,
        }
    }

    /// One-line summary for CLI output.
    pub fn summary_line(&self) -> String {
        format!(
            "N={} | σ(T_D)={:.3} ns | latency={:.1} ns | Q-Q r={:.4} | KS p={:.3} | E={:.0} fJ/Sa",
            self.n,
            self.width_sd_s * 1e9,
            self.mean_latency_s * 1e9,
            self.qq_r,
            self.ks_p,
            self.mean_energy_j * 1e15
        )
    }
}

fn lag1(xs: &[f64]) -> f64 {
    if xs.len() < 3 {
        return 0.0;
    }
    let m = stats::mean(xs);
    let mut num = 0.0;
    let mut den = 0.0;
    for i in 0..xs.len() {
        let d = xs[i] - m;
        den += d * d;
        if i + 1 < xs.len() {
            num += d * (xs[i + 1] - m);
        }
    }
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GrngConfig;
    use crate::grng::circuit::GrngCell;

    #[test]
    fn typical_point_quality_matches_fig8() {
        // Fig. 8: Q–Q r = 0.9967 with N = 2500 at the typical bias.
        let mut cell = GrngCell::ideal(&GrngConfig::default(), 11);
        let samples: Vec<_> = (0..2500).map(|_| cell.sample_fast()).collect();
        let q = QualityReport::from_samples(&samples);
        assert!(q.qq_r > 0.985, "Q-Q r {:.4} should be ≈0.997", q.qq_r);
        assert!(q.lag1_autocorr.abs() < 0.06, "lag1 {}", q.lag1_autocorr);
        assert!(q.ks_p > 0.001, "KS p {}", q.ks_p);
    }

    #[test]
    fn hot_die_quality_collapses() {
        // Tab. I: r-value collapses at 60 °C.
        let mut cfg = GrngConfig::default();
        cfg.temp_c = 60.0;
        // Tab. I operating point is a low bias (µs latencies).
        cfg.bias_v = 0.05;
        let mut cell = GrngCell::ideal(&cfg, 12);
        let samples: Vec<_> = (0..2500).map(|_| cell.sample_fast()).collect();
        let q = QualityReport::from_samples(&samples);
        let mut cfg_cold = cfg.clone();
        cfg_cold.temp_c = 28.0;
        let mut cell_cold = GrngCell::ideal(&cfg_cold, 13);
        let cold: Vec<_> = (0..2500).map(|_| cell_cold.sample_fast()).collect();
        let qc = QualityReport::from_samples(&cold);
        assert!(
            q.qq_r < qc.qq_r,
            "hot r {:.4} should be below cold r {:.4}",
            q.qq_r,
            qc.qq_r
        );
        assert!(q.outlier_frac > qc.outlier_frac);
    }

    #[test]
    fn report_summary_formats() {
        let mut cell = GrngCell::ideal(&GrngConfig::default(), 14);
        let samples: Vec<_> = (0..64).map(|_| cell.sample_fast()).collect();
        let q = QualityReport::from_samples(&samples);
        let line = q.summary_line();
        assert!(line.contains("N=64"));
        assert!(line.contains("fJ/Sa"));
    }
}
