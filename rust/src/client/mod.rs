//! # Client API v1 — the serving surface.
//!
//! Everything a caller needs lives behind this one module: boot a pool
//! with [`Coordinator::builder`], describe work with [`Infer`], follow
//! it with a [`Ticket`], read the verdict in the response's
//! [`UncertaintyReport`], and handle exactly one error type,
//! [`ServeError`]. The CLI, the examples, and the serving benches all
//! route through this surface, so the engines underneath (sim, cim,
//! pjrt) can keep evolving without touching client code.
//!
//! ```no_run
//! use bnn_cim::client::{Backend, Config, Coordinator, Infer};
//!
//! fn main() -> Result<(), Box<dyn std::error::Error>> {
//!     let coord = Coordinator::builder(Config::default())
//!         .backend(Backend::Cim)
//!         .workers(2)
//!         .start()?;
//!     let resp = coord.infer(Infer::new(vec![0.0; 32 * 32]).mc_samples(16))?;
//!     println!(
//!         "class {} | entropy {:.3} nats | deferred: {}",
//!         resp.pred.class,
//!         resp.uncertainty.entropy,
//!         resp.deferred()
//!     );
//!     coord.shutdown();
//!     Ok(())
//! }
//! ```
//!
//! ## Determinism contract
//!
//! For a fixed `(die_seed, workers, mc_workers)` triple, serial
//! workloads replay bit-identically (DESIGN.md §4/§7), and
//! [`Coordinator::submit_many`] is defined as *exactly* a loop of
//! [`Coordinator::submit`] — same admission order, same queue, same
//! batch fusion — so switching a client between the two never moves a
//! single bit.
//!
//! ## Over the wire
//!
//! The same surface is served over HTTP by the network edge
//! ([`EdgeServer`], re-exported here): `POST /v1/infer` carries
//! [`Infer`]'s fields as JSON, responses carry the full
//! [`UncertaintyReport`] with floats encoded losslessly, and every
//! [`ServeError`] maps to a fixed status code
//! ([`crate::edge::status_for`]). Start it with `serve --listen ADDR`
//! or [`EdgeServer::bind`]; DESIGN.md §8 specifies the wire contract.

mod builder;
mod error;
mod infer;
mod ticket;

pub use builder::CoordinatorBuilder;
pub use error::ServeError;
pub use infer::Infer;
pub use ticket::Ticket;

// The rest of the v1 surface: one import path for client code.
pub use crate::bayes::{McPrediction, UncertaintyReport};
pub use crate::config::{Backend, Config};
pub use crate::coordinator::{
    Coordinator, EngineFactory, InferResponse, MetricsSnapshot, ShardHealth, ShardSnapshot,
    SourceFactory,
};
pub use crate::edge::EdgeServer;
pub use crate::fault::FaultPlan;
pub use crate::runtime::EpsilonMode;

impl Coordinator {
    /// Entry point of the v1 surface: a fluent builder over backend,
    /// pool shape, and ε ownership. See [`CoordinatorBuilder::start`]
    /// for the resolution rules.
    pub fn builder(cfg: Config) -> CoordinatorBuilder {
        CoordinatorBuilder::new(cfg)
    }

    /// Submit asynchronously; the [`Ticket`] follows the request.
    pub fn submit(&self, req: Infer) -> Result<Ticket, ServeError> {
        let (id, rx) = self.submit_request(req)?;
        Ok(Ticket::new(id, rx))
    }

    /// Submit a whole workload back to back, preserving batch fusion
    /// (requests land in the queue without waiting in between, so the
    /// dispatcher fuses them under the size/deadline policy exactly as
    /// it would a burst of [`Coordinator::submit`] calls — the replay is
    /// pinned bit-identical in `tests/cim_fidelity.rs`).
    ///
    /// On the first admission failure the error is returned and the
    /// already-issued tickets are dropped; their responses are counted
    /// as `requests_orphaned`, never leaked.
    pub fn submit_many(
        &self,
        reqs: impl IntoIterator<Item = Infer>,
    ) -> Result<Vec<Ticket>, ServeError> {
        let iter = reqs.into_iter();
        let mut tickets = Vec::with_capacity(iter.size_hint().0);
        for req in iter {
            tickets.push(self.submit(req)?);
        }
        Ok(tickets)
    }

    /// Blocking convenience: submit and wait up to
    /// `server.request_timeout_ms`. On [`ServeError::Timeout`] the
    /// ticket is dropped, so the eventual reply is counted as orphaned
    /// rather than leaking into a dead channel unnoticed.
    pub fn infer(&self, req: Infer) -> Result<InferResponse, ServeError> {
        let ticket = self.submit(req)?;
        ticket.wait_timeout(self.request_timeout())
    }
}
