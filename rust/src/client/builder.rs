//! [`CoordinatorBuilder`] — the single way to boot a serving pool.
//!
//! Replaces the six historical `Coordinator::start*` constructors with
//! one fluent surface: backend, pool shape, and ε ownership are
//! orthogonal knobs instead of a constructor per combination. The
//! resolution rules are documented on [`CoordinatorBuilder::start`].

use crate::client::ServeError;
use crate::config::{Backend, Config};
use crate::coordinator::epsilon::EpsilonSupply;
use crate::coordinator::server::{Coordinator, EngineFactory, SourceFactory};
use crate::fault::FaultPlan;
use crate::runtime::{CimEngine, EpsilonMode, InferenceEngine, SharedModelCache, SimEngine};
use std::sync::Arc;

/// Fluent configuration of a [`Coordinator`] pool. Build with
/// `Coordinator::builder(cfg)`, then chain overrides and call `start`.
pub struct CoordinatorBuilder {
    cfg: Config,
    engine_factory: Option<EngineFactory>,
    source_factory: Option<SourceFactory>,
    epsilon: Option<EpsilonMode>,
    fault_plan: Option<FaultPlan>,
}

impl CoordinatorBuilder {
    pub(crate) fn new(cfg: Config) -> Self {
        Self {
            cfg,
            engine_factory: None,
            source_factory: None,
            epsilon: None,
            fault_plan: None,
        }
    }

    /// Engine backend booted per shard (overrides `cfg.server.backend`).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.cfg.server.backend = backend;
        self
    }

    /// Shard workers in the pool (overrides `cfg.server.workers`).
    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.server.workers = n;
        self
    }

    /// MC-parallel replicas per cim engine (overrides
    /// `cfg.server.mc_workers`). Part of the determinism triple
    /// `(die_seed, workers, mc_workers)`.
    pub fn mc_workers(mut self, n: usize) -> Self {
        self.cfg.server.mc_workers = n;
        self
    }

    /// Elastic capacity (overrides `cfg.server.elastic`): autoscale each
    /// shard's MC-replica pool between `min_mc_workers` and
    /// `max_mc_workers` against queue depth, with idle-time work
    /// stealing between shards. Trades the bit-identical replay contract
    /// for a banded one — see DESIGN.md §10.
    pub fn elastic(mut self, on: bool) -> Self {
        self.cfg.server.elastic = on;
        self
    }

    /// Elastic floor for the per-shard replica pool (overrides
    /// `cfg.server.min_mc_workers`).
    pub fn min_mc_workers(mut self, n: usize) -> Self {
        self.cfg.server.min_mc_workers = n;
        self
    }

    /// Elastic ceiling for the per-shard replica pool (overrides
    /// `cfg.server.max_mc_workers`).
    pub fn max_mc_workers(mut self, n: usize) -> Self {
        self.cfg.server.max_mc_workers = n;
        self
    }

    /// Force the ε-ownership mode instead of the backend default:
    /// `External` supplies the default per-shard GRNG-bank sources (what
    /// `sim`/`pjrt` already default to), `InWord` supplies nothing (the
    /// engine's memory arrays must generate ε — the startup handshake
    /// rejects an external-ε engine under an in-word supply). ε
    /// ownership is ultimately the *engine's* property: an external
    /// supply can never be forced onto an in-word engine, so pairing
    /// `External` (or a source factory) with the stock `cim` backend is
    /// rejected at [`Self::start`] instead of being silently ignored.
    pub fn epsilon(mut self, mode: EpsilonMode) -> Self {
        self.epsilon = Some(mode);
        self
    }

    /// Custom per-shard ε sources (ablations: Philox kernel mirror,
    /// Wallace, Box–Muller…). Implies [`EpsilonMode::External`].
    pub fn source_factory(mut self, f: SourceFactory) -> Self {
        self.source_factory = Some(f);
        self
    }

    /// Custom per-shard engines (tests, out-of-tree backends). The
    /// configured `backend` then only selects the default ε supply.
    pub fn engine_factory(mut self, f: EngineFactory) -> Self {
        self.engine_factory = Some(f);
        self
    }

    /// Deterministic fault-injection schedule for chaos testing: every
    /// shard engine is wrapped in a [`crate::fault::FaultyEngine`]
    /// decorator driven by the plan (see [`crate::fault`]'s module docs
    /// for the taxonomy and determinism contract). Overrides both the
    /// `BNN_CIM_FAULT_PLAN` environment variable and the config's
    /// `[faults]` section; pass `FaultPlan::default()` to explicitly
    /// disable injection regardless of either.
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Boot the pool.
    ///
    /// Resolution: the engine comes from [`Self::engine_factory`] if
    /// set, else from `cfg.server.backend` (`sim` → [`SimEngine`],
    /// `cim` → [`CimEngine`] per shard die, `pjrt` → the AOT-artifact
    /// engine, which requires the `pjrt` feature). The ε supply comes
    /// from [`Self::source_factory`] if set, else from
    /// [`Self::epsilon`], else from the backend default (in-word for
    /// `cim`, per-shard GRNG banks otherwise).
    /// The fault plan (chaos testing) resolves builder override >
    /// `BNN_CIM_FAULT_PLAN` env var > config `[faults]`; when the
    /// resolved plan is active every shard engine is wrapped in a
    /// deterministic [`crate::fault::FaultyEngine`] decorator.
    pub fn start(self) -> Result<Coordinator, ServeError> {
        let CoordinatorBuilder {
            mut cfg,
            engine_factory,
            source_factory,
            epsilon,
            fault_plan,
        } = self;
        // The stock cim engine generates ε inside its tile arrays; the
        // worker handshake would silently ignore an external supply, so
        // the caller would believe they measured their source (e.g. a
        // Philox ablation) while serving in-word ε. Reject up front. A
        // custom engine factory may still pair the cim *backend name*
        // with an external-ε engine.
        if cfg.server.backend == Backend::Cim
            && engine_factory.is_none()
            && (source_factory.is_some() || epsilon == Some(EpsilonMode::External))
        {
            return Err(ServeError::Config(
                "external ε supply conflicts with the in-word cim backend: its tile \
                 arrays generate ε in-word and would never consume the source — use \
                 backend sim/pjrt for ε ablations, or a custom engine_factory"
                    .into(),
            ));
        }
        // Fault-plan resolution: builder override > env var > config.
        // The resolved plan is written back into the config so
        // `Coordinator::config()` reports what actually runs.
        let plan = match fault_plan {
            Some(plan) => plan,
            None => match FaultPlan::from_env().map_err(ServeError::from)? {
                Some(plan) => plan,
                None => cfg.faults.clone(),
            },
        };
        plan.validate().map_err(ServeError::from)?;
        cfg.faults = plan.clone();
        let make_engine = match engine_factory {
            Some(f) => f,
            None => default_engine_factory(&cfg)?,
        };
        let make_engine = if plan.active() {
            crate::fault::wrap_engine_factory(make_engine, plan)
        } else {
            make_engine
        };
        let supply = match (source_factory, epsilon) {
            (Some(_), Some(EpsilonMode::InWord)) => {
                return Err(ServeError::Config(
                    "source_factory conflicts with epsilon(InWord): an in-word \
                     engine draws its own ε and would never consume the source"
                        .into(),
                ))
            }
            (Some(f), _) => EpsilonSupply::External(f),
            (None, Some(EpsilonMode::External)) => EpsilonSupply::grng_banks(&cfg.chip),
            (None, Some(EpsilonMode::InWord)) => EpsilonSupply::InWord,
            (None, None) => match cfg.server.backend {
                Backend::Cim => EpsilonSupply::InWord,
                Backend::Sim | Backend::Pjrt => EpsilonSupply::grng_banks(&cfg.chip),
            },
        };
        Coordinator::boot(cfg, make_engine, supply).map_err(ServeError::from)
    }
}

/// The stock engine factory for `cfg.server.backend`.
fn default_engine_factory(cfg: &Config) -> Result<EngineFactory, ServeError> {
    match cfg.server.backend {
        Backend::Sim => {
            let cfg = cfg.clone();
            Ok(Arc::new(move |_shard| {
                Ok(Box::new(SimEngine::from_config(&cfg)) as Box<dyn InferenceEngine>)
            }))
        }
        Backend::Cim => {
            let cfg = cfg.clone();
            // One calibrated-model cache per pool: the boot-time builds
            // populate it, and supervisor respawns clone from it instead
            // of re-running bring-up — Arc-sharing the weight/calibration
            // layer while staying bit-identical to a cold boot.
            let cache = SharedModelCache::new();
            Ok(Arc::new(move |shard| {
                Ok(Box::new(CimEngine::for_shard_cached(&cfg, shard, &cache))
                    as Box<dyn InferenceEngine>)
            }))
        }
        #[cfg(feature = "pjrt")]
        Backend::Pjrt => Ok(crate::coordinator::server::pjrt_engine_factory(cfg)),
        #[cfg(not(feature = "pjrt"))]
        Backend::Pjrt => Err(ServeError::Startup(
            "built without the `pjrt` feature — use .backend(Backend::Sim) \
             (pure-Rust engine) or .backend(Backend::Cim) (behavioral chip model)"
                .into(),
        )),
    }
}
