//! [`Ticket`] — the typed handle to one in-flight request.

use crate::client::ServeError;
use crate::coordinator::InferResponse;
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::Duration;

/// Handle returned by `Coordinator::submit`: the response for request
/// `id` arrives through it exactly once.
///
/// Lifecycle: `wait` consumes the ticket and blocks; `wait_timeout` and
/// `try_wait` borrow it, so a caller can poll or re-arm a deadline
/// without losing the handle. Dropping a ticket abandons the response —
/// the shard worker then finds a dead reply channel, counts the request
/// under `requests_orphaned` in the metrics, and carries on serving.
pub struct Ticket {
    /// Request id (matches [`InferResponse::id`] on the response).
    pub id: u64,
    rx: Receiver<InferResponse>,
}

impl Ticket {
    pub(crate) fn new(id: u64, rx: Receiver<InferResponse>) -> Self {
        Self { id, rx }
    }

    /// Block until the response arrives. [`ServeError::Disconnected`]
    /// means the serving side dropped the reply channel (worker death or
    /// engine failure mid-batch) and the response will never come.
    pub fn wait(self) -> Result<InferResponse, ServeError> {
        self.rx.recv().map_err(|_| ServeError::Disconnected)
    }

    /// Block up to `timeout`. On [`ServeError::Timeout`] the ticket is
    /// still live: keep waiting, or drop it to abandon the request (the
    /// late reply is then counted as orphaned, not leaked).
    pub fn wait_timeout(&self, timeout: Duration) -> Result<InferResponse, ServeError> {
        self.rx.recv_timeout(timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => ServeError::Timeout,
            RecvTimeoutError::Disconnected => ServeError::Disconnected,
        })
    }

    /// Non-blocking poll: `Ok(None)` while the request is in flight.
    pub fn try_wait(&self) -> Result<Option<InferResponse>, ServeError> {
        match self.rx.try_recv() {
            Ok(resp) => Ok(Some(resp)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(ServeError::Disconnected),
        }
    }
}

impl std::fmt::Debug for Ticket {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Ticket").field("id", &self.id).finish_non_exhaustive()
    }
}
