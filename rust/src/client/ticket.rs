//! [`Ticket`] — the typed handle to one in-flight request.

use crate::client::ServeError;
use crate::coordinator::request::Reply;
use crate::coordinator::InferResponse;
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::Duration;

/// Handle returned by `Coordinator::submit`: the response for request
/// `id` arrives through it exactly once.
///
/// Lifecycle: `wait` consumes the ticket and blocks; `wait_timeout` and
/// `try_wait` borrow it, so a caller can poll or re-arm a deadline
/// without losing the handle. Dropping a ticket abandons the response —
/// the shard worker then finds a dead reply channel, counts the request
/// under `requests_orphaned` in the metrics, and carries on serving.
///
/// Failures are *delivered* through the same channel: when a shard dies
/// and the supervisor exhausts the retry budget, a blocked `wait`
/// resolves promptly with [`ServeError::ShardFailed`] rather than
/// hanging until the global request deadline.
pub struct Ticket {
    /// Request id (matches [`InferResponse::id`] on the response).
    pub id: u64,
    rx: Receiver<Reply>,
}

impl Ticket {
    pub(crate) fn new(id: u64, rx: Receiver<Reply>) -> Self {
        Self { id, rx }
    }

    /// Block until the outcome arrives: the response, a typed failure
    /// (e.g. [`ServeError::ShardFailed`] once the supervisor gives up on
    /// the request), or [`ServeError::Disconnected`] if the serving side
    /// dropped the reply channel without delivering either.
    pub fn wait(self) -> Result<InferResponse, ServeError> {
        match self.rx.recv() {
            Ok(reply) => reply.into_result(),
            Err(_) => Err(ServeError::Disconnected),
        }
    }

    /// Block up to `timeout`. On [`ServeError::Timeout`] the ticket is
    /// still live: keep waiting, or drop it to abandon the request (the
    /// late reply is then counted as orphaned, not leaked).
    pub fn wait_timeout(&self, timeout: Duration) -> Result<InferResponse, ServeError> {
        match self.rx.recv_timeout(timeout) {
            Ok(reply) => reply.into_result(),
            Err(RecvTimeoutError::Timeout) => Err(ServeError::Timeout),
            Err(RecvTimeoutError::Disconnected) => Err(ServeError::Disconnected),
        }
    }

    /// Non-blocking poll: `Ok(None)` while the request is in flight.
    pub fn try_wait(&self) -> Result<Option<InferResponse>, ServeError> {
        match self.rx.try_recv() {
            Ok(reply) => reply.into_result().map(Some),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(ServeError::Disconnected),
        }
    }
}

impl std::fmt::Debug for Ticket {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Ticket").field("id", &self.id).finish_non_exhaustive()
    }
}
