//! [`Infer`] — the typed request builder.

/// One classification request, built fluently and handed to
/// [`crate::coordinator::Coordinator::submit`] /
/// [`crate::coordinator::Coordinator::submit_many`] /
/// [`crate::coordinator::Coordinator::infer`].
///
/// Defaults mirror the server config: `mc_samples = 0` means "use
/// `model.mc_samples`", and an unset `defer_threshold` means "judge
/// against `model.defer_threshold`". The per-request threshold override
/// is the scenario-diversity knob: one fleet, per-caller risk tolerance
/// (a triage caller defers aggressively at 0.1 nats while a batch
/// labeler accepts everything at 2.0, against the same pool).
#[derive(Clone, Debug)]
pub struct Infer {
    pub(crate) pixels: Vec<f32>,
    pub(crate) mc_samples: usize,
    pub(crate) defer_threshold: Option<f64>,
    pub(crate) deadline: Option<std::time::Duration>,
}

impl Infer {
    /// A request for `pixels` (grayscale, row-major, side×side in
    /// \[0,1\]) with the server's default MC sample count and deferral
    /// threshold.
    pub fn new(pixels: Vec<f32>) -> Self {
        Self {
            pixels,
            mc_samples: 0,
            defer_threshold: None,
            deadline: None,
        }
    }

    /// Monte-Carlo samples for this request (0 = `model.mc_samples`).
    /// Values above `server.max_mc_samples` are rejected at submit.
    pub fn mc_samples(mut self, t: usize) -> Self {
        self.mc_samples = t;
        self
    }

    /// Per-request deferral threshold \[nats\], overriding
    /// `model.defer_threshold`. Must be finite and within `[0, 10]`
    /// (checked at submit, like the config default).
    pub fn defer_threshold(mut self, nats: f64) -> Self {
        self.defer_threshold = Some(nats);
        self
    }

    /// End-to-end deadline for this request, fixed at admission
    /// (default: `server.request_timeout_ms`). The budget survives
    /// failure recovery: a request redelivered after a shard death keeps
    /// its *original* deadline, so retries never stretch the caller's
    /// time bound (DESIGN.md §9).
    pub fn deadline(mut self, budget: std::time::Duration) -> Self {
        self.deadline = Some(budget);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_defer_to_the_server() {
        let req = Infer::new(vec![0.0; 4]);
        assert_eq!(req.mc_samples, 0);
        assert_eq!(req.defer_threshold, None);
        assert_eq!(req.deadline, None);
        let req = Infer::new(vec![0.0; 4])
            .mc_samples(12)
            .defer_threshold(0.3)
            .deadline(std::time::Duration::from_millis(250));
        assert_eq!(req.mc_samples, 12);
        assert_eq!(req.defer_threshold, Some(0.3));
        assert_eq!(req.deadline, Some(std::time::Duration::from_millis(250)));
    }
}
