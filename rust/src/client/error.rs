//! [`ServeError`] — the one error type the serving surface speaks.
//!
//! Before API v1 a client saw three failure languages at once:
//! [`RejectReason`] values from `submit`, `crate::error::Error` (or
//! stringly `Box<dyn Error>`) from the constructors, and silent channel
//! drops from workers that died mid-batch. `ServeError` absorbs all
//! three behind one `std::error::Error` implementation, so `?` works
//! end to end and callers can still match on the precise failure mode.

use crate::coordinator::RejectReason;

/// Unified client-facing serving error: admission, wait, and startup
/// failures of the coordinator pool.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// Request queue at capacity (backpressure) — retry later.
    QueueFull,
    /// Pixel payload does not match `model.image_side²`.
    WrongShape { expected: usize, got: usize },
    /// Per-request `mc_samples` above `server.max_mc_samples` — rejected
    /// up front so one greedy request cannot inflate the MC pass count
    /// of the whole fused batch.
    McSamplesTooLarge { max: usize, got: usize },
    /// Per-request `defer_threshold` outside the valid `[0, 10]` nats
    /// range (or non-finite) — same bound `Config::validate` enforces
    /// for the server-wide default.
    InvalidDeferThreshold { got: f64 },
    /// The pool is shutting down; no new work is admitted.
    ShuttingDown,
    /// No response within the deadline. The request may still complete
    /// server-side; its reply is then counted as `requests_orphaned`.
    Timeout,
    /// The serving side dropped the reply channel (worker death or
    /// engine failure mid-batch) — the response will never arrive.
    Disconnected,
    /// The shard serving this request died (or kept failing) and the
    /// per-request retry budget (`server.retry_budget`) is exhausted —
    /// delivered as a typed reply by the supervisor, so waits resolve
    /// promptly instead of running out their own deadline. Inference is
    /// pure: resubmitting the same request is always safe.
    ShardFailed { shard: usize },
    /// Invalid configuration or an inconsistent builder combination.
    Config(String),
    /// The pool failed to boot: engine load, worker spawn, or a backend
    /// compiled out (e.g. `pjrt` without the feature).
    Startup(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::QueueFull => write!(f, "queue full (backpressure)"),
            ServeError::WrongShape { expected, got } => {
                write!(f, "wrong input shape: expected {expected} pixels, got {got}")
            }
            ServeError::McSamplesTooLarge { max, got } => {
                write!(f, "mc_samples {got} exceeds server.max_mc_samples {max}")
            }
            ServeError::InvalidDeferThreshold { got } => {
                write!(f, "defer_threshold {got} outside [0, 10] nats")
            }
            ServeError::ShuttingDown => write!(f, "server shutting down"),
            ServeError::Timeout => write!(f, "request timed out"),
            ServeError::Disconnected => {
                write!(f, "serving side dropped the reply channel")
            }
            ServeError::ShardFailed { shard } => {
                write!(f, "shard {shard} failed and the retry budget is exhausted")
            }
            ServeError::Config(s) => write!(f, "configuration error: {s}"),
            ServeError::Startup(s) => write!(f, "startup error: {s}"),
        }
    }
}

impl std::error::Error for ServeError {}

impl From<RejectReason> for ServeError {
    fn from(r: RejectReason) -> Self {
        match r {
            RejectReason::QueueFull => ServeError::QueueFull,
            RejectReason::WrongShape { expected, got } => {
                ServeError::WrongShape { expected, got }
            }
            RejectReason::McSamplesTooLarge { max, got } => {
                ServeError::McSamplesTooLarge { max, got }
            }
            RejectReason::ShuttingDown => ServeError::ShuttingDown,
            RejectReason::Timeout => ServeError::Timeout,
        }
    }
}

impl From<crate::error::Error> for ServeError {
    fn from(e: crate::error::Error) -> Self {
        match e {
            crate::error::Error::Config(s) => ServeError::Config(s),
            other => ServeError::Startup(other.to_string()),
        }
    }
}

/// Reverse direction: keeps the deprecated `Coordinator::start*`
/// constructors' historical `crate::error::Result` signatures compiling
/// as one-line shims over the builder.
impl From<ServeError> for crate::error::Error {
    fn from(e: ServeError) -> Self {
        match e {
            ServeError::Config(s) => crate::error::Error::Config(s),
            other => crate::error::Error::Coordinator(other.to_string()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absorbs_every_reject_reason() {
        let pairs: Vec<(RejectReason, ServeError)> = vec![
            (RejectReason::QueueFull, ServeError::QueueFull),
            (
                RejectReason::WrongShape { expected: 4, got: 5 },
                ServeError::WrongShape { expected: 4, got: 5 },
            ),
            (
                RejectReason::McSamplesTooLarge { max: 8, got: 9 },
                ServeError::McSamplesTooLarge { max: 8, got: 9 },
            ),
            (RejectReason::ShuttingDown, ServeError::ShuttingDown),
            (RejectReason::Timeout, ServeError::Timeout),
        ];
        for (reason, expected) in pairs {
            let display = reason.to_string();
            let converted = ServeError::from(reason);
            assert_eq!(converted, expected);
            // Messages stay stable across the migration.
            assert_eq!(converted.to_string(), display);
        }
    }

    #[test]
    fn config_errors_round_trip_their_category() {
        let e = ServeError::from(crate::error::Error::Config("bad".into()));
        assert_eq!(e, ServeError::Config("bad".into()));
        match crate::error::Error::from(e) {
            crate::error::Error::Config(s) => assert_eq!(s, "bad"),
            other => panic!("lost the config category: {other}"),
        }
    }

    #[test]
    fn is_a_std_error() {
        fn assert_error<E: std::error::Error + Send + Sync + 'static>() {}
        assert_error::<ServeError>();
        // `?` into the examples' Box<dyn Error> works.
        let boxed: Box<dyn std::error::Error> = ServeError::Timeout.into();
        assert!(boxed.to_string().contains("timed out"));
    }
}
