//! Coordinator-level deferral-path coverage (ISSUE 5): the Fig. 1
//! defer-to-human loop, end to end through the serving surface.
//!
//! Pins three things no other test exercised:
//! 1. The policy identity — `deferred == (entropy > threshold)`, strict
//!    at the boundary — judged *inside* the serving loop and surfaced in
//!    the response's `UncertaintyReport`.
//! 2. The per-request `defer_threshold` override beating the server-wide
//!    `model.defer_threshold` (one fleet, per-caller risk tolerance).
//! 3. The decomposition identity on served responses:
//!    `epistemic == (entropy − aleatoric).max(0)`.
//!
//! Everything runs on the deterministic `SimEngine` (fixed `die_seed`,
//! one worker, serial submits), so replayed requests land bit-identical
//! entropies — the boundary test relies on that.

use bnn_cim::client::{Backend, Config, Coordinator, Infer};
use bnn_cim::data::SyntheticPerson;

fn sim_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.model.mc_samples = 8;
    cfg.server.batch_deadline_ms = 1.0;
    cfg
}

fn pixels() -> Vec<f32> {
    SyntheticPerson::new(32, 71).sample(3).pixels
}

/// One blocking request on a fresh single-worker sim pool; the fixed
/// seeds make repeated calls bit-identical.
fn infer_once(req: Infer) -> bnn_cim::client::InferResponse {
    let coord = Coordinator::builder(sim_cfg())
        .backend(Backend::Sim)
        .start()
        .unwrap();
    let resp = coord.infer(req).unwrap();
    coord.shutdown();
    resp
}

#[test]
fn report_carries_the_server_default_threshold_and_the_identities() {
    let cfg = sim_cfg();
    let resp = infer_once(Infer::new(pixels()));
    let u = &resp.uncertainty;
    // Threshold used = the server default (no override given).
    assert_eq!(u.threshold, cfg.model.defer_threshold);
    // Policy identity, as served.
    assert_eq!(u.deferred, u.entropy > u.threshold);
    assert_eq!(resp.deferred(), u.deferred);
    // The report mirrors the prediction's decomposition…
    assert_eq!(u.entropy, resp.pred.entropy);
    assert_eq!(u.aleatoric, resp.pred.expected_entropy);
    assert_eq!(u.epistemic, resp.pred.mutual_information);
    // …and the decomposition identity holds, clamped at zero.
    assert_eq!(u.epistemic, (u.entropy - u.aleatoric).max(0.0));
    assert!(u.epistemic >= 0.0);
    // MC over a stochastic head never collapses to a point mass.
    assert!(u.entropy > 0.0, "sim-engine MC entropy must be positive");
}

#[test]
fn per_request_override_beats_the_server_default() {
    // Max-lax caller: nothing defers at the top of the valid range.
    let lax = infer_once(Infer::new(pixels()).defer_threshold(10.0));
    assert_eq!(lax.uncertainty.threshold, 10.0);
    assert!(!lax.deferred());
    // Zero-tolerance caller: any positive entropy defers.
    let strict = infer_once(Infer::new(pixels()).defer_threshold(0.0));
    assert_eq!(strict.uncertainty.threshold, 0.0);
    assert!(strict.uncertainty.entropy > 0.0);
    assert!(strict.deferred(), "entropy > 0 must defer at threshold 0");
    // Same die, same request: only the judgment differed.
    assert_eq!(lax.uncertainty.entropy, strict.uncertainty.entropy);
    assert_eq!(lax.pred.probs, strict.pred.probs);
}

#[test]
fn threshold_boundary_is_strict_end_to_end() {
    // Probe the entropy this exact request produces…
    let probe = infer_once(Infer::new(pixels()));
    let h = probe.uncertainty.entropy;
    assert!(h > 0.0 && h < 10.0, "probe entropy {h} outside testable range");
    // …then replay with the bar at exactly that entropy: kept (strict >).
    let at = infer_once(Infer::new(pixels()).defer_threshold(h));
    assert_eq!(at.uncertainty.entropy, h, "fixed seeds must replay bitwise");
    assert!(!at.deferred(), "entropy == threshold must NOT defer");
    // One float step below the entropy: deferred.
    let below = f64::from_bits(h.to_bits() - 1);
    let just_under = infer_once(Infer::new(pixels()).defer_threshold(below));
    assert!(just_under.deferred(), "entropy > threshold by 1 ulp must defer");
}
