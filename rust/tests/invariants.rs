//! Property-based invariants over the core data structures and the
//! coordinator-adjacent math (propcheck mini-framework — proptest is
//! unavailable offline; see DESIGN.md §6).

use bnn_cim::bayes::{aggregate_mc, softmax};
use bnn_cim::cim::{CimTile, MuWord, MvmOptions, SigmaWord, WeightScale};
use bnn_cim::config::ChipConfig;
use bnn_cim::util::json::Json;
use bnn_cim::util::propcheck::{assert_close, property, Gen};

#[test]
fn json_roundtrips_arbitrary_trees() {
    property("json roundtrip", 120, |g| {
        let v = random_json(g, 3);
        let text = v.to_string_pretty();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("reparse failed: {e}\n{text}"));
        assert_eq!(v, back);
        // compact form too
        let back2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, back2);
    });
}

fn random_json(g: &mut Gen, depth: usize) -> Json {
    let choice = if depth == 0 {
        g.usize_in(0, 3)
    } else {
        g.usize_in(0, 5)
    };
    match choice {
        0 => Json::Null,
        1 => Json::Bool(g.bool()),
        2 => Json::Num((g.f64_in(-1e9, 1e9) * 1000.0).round() / 1000.0),
        3 => Json::Str(random_string(g)),
        4 => Json::Arr((0..g.usize_in(0, 4)).map(|_| random_json(g, depth - 1)).collect()),
        _ => {
            let mut o = Json::obj();
            for _ in 0..g.usize_in(0, 4) {
                o.set(&random_string(g), random_json(g, depth - 1));
            }
            o
        }
    }
}

fn random_string(g: &mut Gen) -> String {
    let alphabet = ['a', 'Z', '0', ' ', '_', '"', '\\', 'é', '\n', '😀'];
    (0..g.usize_in(0, 8))
        .map(|_| *g.pick(&alphabet))
        .collect()
}

#[test]
fn mu_word_quantization_is_projection() {
    // Quantizing twice = quantizing once, error ≤ grid step, sign kept.
    property("mu quantize projection", 300, |g| {
        let x = g.f64_in(-400.0, 400.0);
        let w = MuWord::quantize(x, 8);
        let v = w.value() as f64;
        let w2 = MuWord::quantize(v, 8);
        assert_eq!(w.value(), w2.value(), "idempotence");
        if x.abs() <= 255.0 {
            assert!((v - x).abs() <= 1.0 + 1e-9, "x={x} v={v}");
        }
        assert_eq!(v.abs() as i32 % 2, 1, "grid holds odd integers only");
    });
}

#[test]
fn sigma_word_monotone() {
    property("sigma quantize monotone", 200, |g| {
        let a = g.f64_in(0.0, 20.0);
        let b = g.f64_in(0.0, 20.0);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        assert!(
            SigmaWord::quantize(lo, 4).value() <= SigmaWord::quantize(hi, 4).value(),
            "monotonicity at {lo} vs {hi}"
        );
    });
}

#[test]
fn weight_scale_roundtrip_bounded_error() {
    property("weight scale roundtrip", 200, |g| {
        let mu_max = g.f64_in(0.05, 10.0);
        let sg_max = g.f64_in(0.01, 2.0);
        let ws = WeightScale::fit(mu_max, sg_max, 8, 4);
        let mu = g.f64_in(-mu_max, mu_max);
        let back = ws.decode_mu(ws.encode_mu(mu).value() as f64);
        assert!(
            (back - mu).abs() <= 1.01 / ws.mu_scale,
            "μ={mu} back={back}"
        );
        let sg = g.f64_in(0.0, sg_max);
        let back_s = ws.decode_sigma(ws.encode_sigma(sg).value() as f64);
        assert!(
            (back_s - sg).abs() <= 0.51 / ws.sigma_scale,
            "σ={sg} back={back_s}"
        );
    });
}

#[test]
fn softmax_and_aggregation_invariants() {
    property("mc aggregation invariants", 150, |g| {
        let k = g.usize_in(2, 5);
        let t = g.usize_in(1, 8);
        let samples: Vec<Vec<f64>> = (0..t)
            .map(|_| softmax(&(0..k).map(|_| g.f64_in(-8.0, 8.0)).collect::<Vec<_>>()))
            .collect();
        let pred = aggregate_mc(&samples);
        assert_close(pred.probs.iter().sum::<f64>(), 1.0, 1e-9, 1e-9);
        assert!(pred.entropy >= -1e-12 && pred.entropy <= (k as f64).ln() + 1e-9);
        assert!(pred.mutual_information >= 0.0, "MI must be non-negative");
        assert!(pred.mutual_information <= pred.entropy + 1e-9);
        assert!(pred.class < k);
        assert_close(pred.confidence, pred.probs[pred.class], 1e-12, 1e-12);
    });
}

#[test]
fn tile_mvm_zero_input_is_silent() {
    // X = 0 draws no current: both paths must read ≈ 0 after calibration
    // regardless of programmed weights.
    let mut chip = ChipConfig::default();
    chip.tile.rows = 16;
    chip.tile.words_per_row = 4;
    let mut tile = CimTile::new(&chip);
    bnn_cim::cim::calibrate(&mut tile, 32, 16).unwrap();
    property("zero input silent", 20, |g| {
        let n = 16 * 4;
        let mu: Vec<f64> = (0..n).map(|_| g.f64_in(-255.0, 255.0)).collect();
        let sg: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 15.0)).collect();
        tile.program_matrix(&mu, &sg);
        let y = tile.mvm(&[0u8; 16], MvmOptions::default());
        for (m, s) in y.mu.iter().zip(y.sigma.iter()) {
            // Residual = ADC noise (≤ ~0.5 LSB/plane) only.
            assert!(m.abs() < 600.0, "μ path leaked {m}");
            assert!(s.abs() < 600.0, "σε path leaked {s}");
        }
    });
}

#[test]
fn tile_ideal_analog_tracks_reference_within_quantization() {
    // NOTE: per-bit-plane ADCs with clipping are NOT monotone in the
    // inputs (a saturated MSB plane can mask lower-plane increments), so
    // the honest invariant is: with ideal converters and inputs that keep
    // every plane inside full scale, the analog output equals the digital
    // reference up to per-plane rounding.
    let mut chip = ChipConfig::default();
    chip.tile.rows = 8;
    chip.tile.words_per_row = 2;
    let mut tile = CimTile::new(&chip);
    // Per-plane FS: rows·x_max·0.25 charge units; with x ≤ 3 the worst
    // plane charge is 8·3 = 24 < 30, so nothing clips.
    property("mvm ideal tracks reference", 40, |g| {
        let n = 8 * 2;
        let mu: Vec<f64> = (0..n).map(|_| g.f64_in(-255.0, 255.0)).collect();
        tile.program_matrix(&mu, &vec![0.0; n]);
        let opts = MvmOptions {
            bayesian: false,
            refresh_epsilon: false,
            ideal_analog: true,
        };
        let x: Vec<u8> = (0..8).map(|_| g.usize_in(0, 3) as u8).collect();
        let y = tile.mvm(&x, opts);
        let r = tile.mvm_reference(&x, false);
        // Max reconstruction error: Σ_b 2^b · lsb/2 over 8 planes.
        let lsb = 8.0 * 15.0 * 0.25 / 32.0;
        let bound = 255.0 * lsb / 2.0 + 1e-9;
        for (a, b) in y.mu.iter().zip(r.mu.iter()) {
            assert!(
                (a - b).abs() <= bound,
                "ideal-analog error {} exceeds quantization bound {bound}",
                (a - b).abs()
            );
        }
    });
}

#[test]
fn toml_numbers_roundtrip_through_config() {
    property("toml config override", 100, |g| {
        let bias = (g.f64_in(0.01, 0.4) * 1e4).round() / 1e4;
        let rows = g.usize_in(8, 128);
        let text = format!("[chip.grng]\nbias_v = {bias}\n[chip.tile]\nrows = {rows}\n");
        let cfg = bnn_cim::config::Config::from_toml_str(&text).unwrap();
        assert_close(cfg.chip.grng.bias_v, bias, 1e-12, 1e-12);
        assert_eq!(cfg.chip.tile.rows, rows);
    });
}
