//! Property tests for the pure batch/slot-packing cores
//! (`coordinator::batch`), via the offline `util::propcheck` harness:
//!
//! - slot packing round-trips requests: every (request, MC-pass) pair
//!   occupies exactly one slot, requests laid out request-major;
//! - no call ever exceeds the artifact batch capacity, and only the last
//!   call may be partial;
//! - `effective_t` respects `server.max_mc_samples` for arbitrary request
//!   mixes that passed the submit-time bound.

use bnn_cim::coordinator::batch::{effective_t, pack_images, plan_calls, scatter_features};
use bnn_cim::util::propcheck::{property, Gen};

#[test]
fn plan_calls_round_trips_every_request_pass_pair() {
    property("plan round-trips (request, pass) pairs", 300, |g| {
        let n_requests = g.usize_in(1, 12);
        let t = g.usize_in(1, 24);
        let art_batch = g.usize_in(1, 16);
        let plan = plan_calls(n_requests, t, art_batch);
        // Exactly ceil(n·t / B) calls.
        assert_eq!(plan.len(), (n_requests * t).div_ceil(art_batch));
        let mut passes_per_request = vec![0usize; n_requests];
        let mut flat = Vec::new();
        for (ci, owners) in plan.iter().enumerate() {
            // Capacity is never exceeded…
            assert!(
                owners.len() <= art_batch,
                "call {ci} packs {} > {art_batch} slots",
                owners.len()
            );
            // …and only the final call may be partial.
            if ci + 1 < plan.len() {
                assert_eq!(owners.len(), art_batch, "call {ci} under-filled early");
            }
            for &r in owners {
                assert!(r < n_requests, "owner {r} out of range");
                passes_per_request[r] += 1;
                flat.push(r);
            }
        }
        // Round trip: every request got exactly its t passes…
        assert_eq!(passes_per_request, vec![t; n_requests]);
        // …in request-major order (request 0's passes first).
        let mut sorted = flat.clone();
        sorted.sort_unstable();
        assert_eq!(flat, sorted, "pairs must be laid out request-major");
    });
}

#[test]
fn effective_t_respects_max_mc_samples_for_arbitrary_mixes() {
    property("effective_t bounded by max_mc_samples", 300, |g| {
        let max_mc = g.usize_in(1, 64);
        let default_t = g.usize_in(1, max_mc);
        let n = g.usize_in(1, 10);
        // Mixes that passed the submit-time bound: 0 (= server default)
        // or 1..=max_mc.
        let mc: Vec<usize> = (0..n)
            .map(|_| {
                if g.bool() {
                    0
                } else {
                    g.usize_in(1, max_mc)
                }
            })
            .collect();
        let t = effective_t(&mc, default_t);
        assert!(t >= 1, "a fused batch always runs at least one pass");
        assert!(
            t <= max_mc,
            "effective t={t} exceeds max_mc_samples={max_mc} for mix {mc:?}"
        );
        // t is the max over substituted members.
        let expect = mc
            .iter()
            .map(|&m| if m == 0 { default_t } else { m })
            .max()
            .unwrap();
        assert_eq!(t, expect);
    });
}

#[test]
fn pack_and_scatter_round_trip_request_payloads() {
    property("pack_images + scatter_features round-trip", 200, |g| {
        let ppi = g.usize_in(1, 16);
        let art_batch = g.usize_in(1, 8);
        let n = g.usize_in(1, art_batch);
        let images: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..ppi).map(|_| g.f32_in(-1.0, 1.0)).collect())
            .collect();
        let refs: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();
        let packed = pack_images(&refs, art_batch, ppi);
        assert_eq!(packed.len(), art_batch * ppi);
        for (i, img) in images.iter().enumerate() {
            assert_eq!(&packed[i * ppi..(i + 1) * ppi], img.as_slice());
        }
        // Tail slots are zero-filled.
        assert!(packed[n * ppi..].iter().all(|&v| v == 0.0));

        // Scattering replicates each owner's feature row into its slot.
        let feat_dim = g.usize_in(1, 8);
        let feats: Vec<f32> = (0..n * feat_dim).map(|_| g.f32_in(-2.0, 2.0)).collect();
        let owners: Vec<usize> = (0..art_batch).map(|_| g.usize_in(0, n - 1)).collect();
        let mut out = vec![0.0f32; art_batch * feat_dim];
        scatter_features(&feats, &owners, feat_dim, &mut out);
        for (slot, &owner) in owners.iter().enumerate() {
            assert_eq!(
                &out[slot * feat_dim..(slot + 1) * feat_dim],
                &feats[owner * feat_dim..(owner + 1) * feat_dim],
                "slot {slot} lost request {owner}'s features"
            );
        }
    });
}
