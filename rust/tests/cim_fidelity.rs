//! Cross-backend fidelity harness (ISSUE 2 acceptance):
//!
//! 1. `CimEngine` MVMs agree with the exact digital reference
//!    (`TileArray::mvm_reference`) within calibration tolerance — the
//!    analog chain (IDAC, σε subarray, SAR ADCs, reduction) tracks the
//!    mathematical MVM it approximates, deterministic and Bayesian paths
//!    both.
//! 2. The cim serving backend is bit-deterministic for a fixed
//!    `(die_seed, workers, mc_workers)` triple: serial workloads replay
//!    identically — including through the double-buffered ε pipeline
//!    (same-feature MC slots batched per replica, ε for sample k+1
//!    produced while sample k's MVM converts).
//! 3. Serving through `--backend cim` surfaces nonzero per-shard energy
//!    (fJ/Sample) in `MetricsSnapshot`, and snapshot reads never reset
//!    the counters.
//! 4. The client API v1 determinism contract: `submit_many` replays
//!    bit-identical to a sequential `submit` loop for the same fixed
//!    triple.
//!
//! Everything runs artifact-free on small tiles so bring-up calibration
//! stays cheap in debug builds.

use bnn_cim::cim::MvmOptions;
use bnn_cim::client::{Backend, Config, Coordinator, Infer};
use bnn_cim::data::SyntheticPerson;
use bnn_cim::runtime::CimEngine;
use bnn_cim::util::rng::{Pcg64, Rng64};
use bnn_cim::util::stats::pearson;

/// Small tiles: 16×4 instead of 64×8, cheap to calibrate.
fn small_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.chip.tile.rows = 16;
    cfg.chip.tile.words_per_row = 4;
    cfg.model.mc_samples = 4;
    cfg.server.max_batch = 4;
    cfg.server.batch_deadline_ms = 1.0;
    cfg
}

fn random_codes(n: usize, max_excl: u64, seed: u64) -> Vec<u8> {
    let mut rng = Pcg64::new(seed);
    (0..n).map(|_| rng.next_below(max_excl) as u8).collect()
}

/// Full-size (64×8) tiles with small serving parameters: the ε/MVM
/// pipeline only engages on banks of at least `EPSILON_PIPELINE_MIN_CELLS`
/// cells, so the double-buffered-path pins below must run the real tile
/// geometry (bring-up calibration is slower but still sub-second under
/// the test profile's opt-level).
fn full_tile_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.model.mc_samples = 4;
    cfg.server.max_batch = 4;
    cfg.server.batch_deadline_ms = 1.0;
    cfg
}

#[test]
fn cim_mvm_tracks_reference_within_calibration_tolerance() {
    let cfg = small_cfg();
    let mut engine = CimEngine::from_config(&cfg);
    let in_dim = engine.model().head[0].in_dim;
    let model = engine.model_mut();
    let arr = model.head[0]
        .hw_array_mut()
        .expect("CimEngine maps the head at construction");

    // Deterministic path (σε disabled, held ε): the calibrated analog
    // chain must track the digital reference closely.
    let det_opts = MvmOptions {
        bayesian: false,
        refresh_epsilon: false,
        ideal_analog: false,
    };
    let mut ys = Vec::new();
    let mut refs = Vec::new();
    for s in 0..12 {
        let x = random_codes(in_dim, 16, 100 + s);
        ys.extend(arr.mvm(&x, det_opts).combined());
        refs.extend(arr.mvm_reference(&x, false).combined());
    }
    let r = pearson(&ys, &refs);
    assert!(
        r > 0.97,
        "deterministic CIM MVM must track mvm_reference, r={r}"
    );

    // Bayesian path: fresh in-word ε per MVM; the reference reuses the
    // same ε matrix, so agreement is within analog tolerance only.
    let bay_opts = MvmOptions {
        bayesian: true,
        refresh_epsilon: true,
        ideal_analog: false,
    };
    let mut ys_b = Vec::new();
    let mut refs_b = Vec::new();
    for s in 0..12 {
        let x = random_codes(in_dim, 16, 500 + s);
        ys_b.extend(arr.mvm(&x, bay_opts).combined());
        refs_b.extend(arr.mvm_reference(&x, true).combined());
    }
    let rb = pearson(&ys_b, &refs_b);
    assert!(
        rb > 0.9,
        "Bayesian CIM MVM must track same-ε mvm_reference, r={rb}"
    );
}

#[test]
fn cim_backend_replays_bitwise_for_fixed_die_seed_and_workers() {
    // The determinism triple now includes the engine-level MC fan-out:
    // replay is bit-identical for a fixed (die_seed, workers, mc_workers)
    // even though each shard's head samples run on 3 parallel replicas.
    let run = || {
        let cfg = small_cfg();
        let coord = Coordinator::builder(cfg.clone())
            .backend(Backend::Cim)
            .workers(2)
            .mc_workers(3)
            .start()
            .unwrap();
        let gen = SyntheticPerson::new(cfg.model.image_side, 44);
        let mut out = Vec::new();
        for i in 0..6 {
            let resp = coord.infer(Infer::new(gen.sample(i).pixels)).unwrap();
            out.push(resp.pred.probs);
        }
        coord.shutdown();
        out
    };
    assert_eq!(
        run(),
        run(),
        "cim backend must replay bitwise for a fixed (die_seed, workers)"
    );
}

#[test]
fn double_buffered_head_batch_matches_sequential_bitwise() {
    // The engine's batched MC path (head_samples_hw → forward_hw_mc →
    // the tiles' double-buffered mvm_batch pipeline; t = 6 ≥ the
    // pipeline threshold, full-size 512-cell banks ≥ the cells floor)
    // must be bit-identical to sequential single-sample head passes on
    // a twin engine.
    let cfg = full_tile_cfg();
    let mut batched = CimEngine::from_config(&cfg);
    let mut serial = CimEngine::from_config(&cfg);
    let px = vec![0.45f32; cfg.model.image_side * cfg.model.image_side];
    let feats = batched.model().forward_features(&px);
    let t = 6;
    let ys = batched.model_mut().head_samples_hw(&feats, t);
    assert_eq!(ys.len(), t);
    for (s, y) in ys.iter().enumerate() {
        assert_eq!(
            y,
            &serial.model_mut().head_sample_hw(&feats),
            "sample {s}/{t} diverged through the ε pipeline"
        );
    }
}

#[test]
fn cim_backend_replays_bitwise_through_the_batched_mc_path() {
    // mc_workers = 1 gives each fused head call one replica owning all
    // its slots; the packer replicates one request's features across its
    // MC-pass slots, so the replica collapses them into a single batched
    // run (t = 4 ≥ the pipeline threshold, on full-size banks ≥ the
    // cells floor) — the serving-side double-buffered engine path.
    // Replay must stay bit-identical for the fixed
    // (die_seed, workers, mc_workers) triple.
    let run = || {
        let cfg = full_tile_cfg();
        let coord = Coordinator::builder(cfg.clone())
            .backend(Backend::Cim)
            .workers(2)
            .mc_workers(1)
            .start()
            .unwrap();
        let gen = SyntheticPerson::new(cfg.model.image_side, 91);
        let mut out = Vec::new();
        for i in 0..6 {
            let resp = coord.infer(Infer::new(gen.sample(i).pixels)).unwrap();
            out.push(resp.pred.probs);
        }
        coord.shutdown();
        out
    };
    assert_eq!(
        run(),
        run(),
        "double-buffered cim path must replay bitwise for a fixed triple"
    );
}

/// `submit_many` is defined as exactly a loop of `submit`: same admission
/// order, same queue, same batch fusion. Pin the contract bit-exactly on
/// the cim backend for a fixed `(die_seed, workers, mc_workers)` triple.
/// Batch assembly is made deterministic by sizing `max_batch` to the
/// workload and giving the dispatcher a generous deadline, so each arm
/// fuses all requests into one batch regardless of timing.
#[test]
fn submit_many_replays_bit_identical_to_sequential_submit() {
    let n: usize = 4;
    let mk = |n: usize| {
        let mut cfg = small_cfg();
        cfg.server.backend = Backend::Cim;
        cfg.server.workers = 2;
        cfg.server.mc_workers = 2;
        cfg.server.max_batch = n;
        cfg.server.batch_deadline_ms = 2000.0;
        cfg
    };
    let gen = SyntheticPerson::new(mk(n).model.image_side, 44);
    let workload = |gen: &SyntheticPerson| -> Vec<Infer> {
        (0..n as u64)
            .map(|i| Infer::new(gen.sample(i).pixels).mc_samples(3))
            .collect()
    };

    let via_many = {
        let coord = Coordinator::builder(mk(n)).start().unwrap();
        let tickets = coord.submit_many(workload(&gen)).unwrap();
        let out: Vec<Vec<f64>> = tickets
            .into_iter()
            .map(|t| t.wait().unwrap().pred.probs)
            .collect();
        coord.shutdown();
        out
    };
    let via_sequential = {
        let coord = Coordinator::builder(mk(n)).start().unwrap();
        let tickets: Vec<_> = workload(&gen)
            .into_iter()
            .map(|req| coord.submit(req).unwrap())
            .collect();
        let out: Vec<Vec<f64>> = tickets
            .into_iter()
            .map(|t| t.wait().unwrap().pred.probs)
            .collect();
        coord.shutdown();
        out
    };
    assert_eq!(
        via_many, via_sequential,
        "submit_many must be bit-identical to a sequential submit loop"
    );
}

#[test]
fn cim_backend_serves_with_nonzero_per_shard_energy() {
    let cfg = small_cfg();
    let coord = Coordinator::builder(cfg.clone())
        .backend(Backend::Cim)
        .workers(2)
        .start()
        .unwrap();
    let gen = SyntheticPerson::new(cfg.model.image_side, 7);
    for i in 0..6 {
        let resp = coord.infer(Infer::new(gen.sample(i).pixels)).unwrap();
        assert_eq!(resp.pred.probs.len(), cfg.model.classes);
        assert!(
            resp.energy_j > 0.0,
            "cim request {i} must carry its tile-energy share"
        );
    }
    let m = coord.metrics();
    assert_eq!(m.requests_total, 6);
    assert!(m.engine_energy_j > 0.0, "tile ledgers must surface");
    assert!(m.engine_j_per_op() > 0.0);
    // Serial round-robin over 2 shards: both saw traffic, and each
    // traffic-bearing shard reports in-word ε energy (the paper's
    // fJ/Sample headline, live at serving time).
    assert_eq!(m.per_shard.len(), 2);
    for s in &m.per_shard {
        assert!(s.requests > 0, "round-robin must exercise shard {}", s.shard);
        assert!(s.epsilon_samples > 0, "shard {} drew no ε", s.shard);
        assert!(s.epsilon_energy_j > 0.0);
        assert!(s.engine_energy_j > 0.0);
        let fj = s.epsilon_fj_per_sample();
        assert!(
            (100.0..1000.0).contains(&fj),
            "shard {} fJ/Sample {fj:.0} out of hardware range (≈360)",
            s.shard
        );
    }
    // Snapshots are non-destructive: a second read sees the same energy.
    let m2 = coord.metrics();
    assert_eq!(m.engine_energy_j, m2.engine_energy_j);
    assert_eq!(m.epsilon_energy_j, m2.epsilon_energy_j);
    assert_eq!(m.epsilon_samples, m2.epsilon_samples);
    coord.shutdown();
}
