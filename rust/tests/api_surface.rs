//! Compile-time snapshot of the client API v1 surface (ISSUE 5).
//!
//! Two layers of protection:
//!
//! 1. `v1_*` tests pin every v1 export by name *and* signature through
//!    function-pointer coercions and struct destructuring — an
//!    accidental breaking change (renamed method, moved field, changed
//!    error type) stops this file from compiling.
//! 2. The shim-equivalence tests pin the `#[deprecated]` pre-v1
//!    constructors bit-identical to their builder replacements, so the
//!    deprecation window cannot drift. They are the only remaining
//!    callers of the old constructors — each carries its own
//!    item-scoped `#[allow(deprecated)]` so a *new* deprecated call
//!    anywhere else in this file still warns.

use bnn_cim::client::{
    Backend, Config, Coordinator, CoordinatorBuilder, EngineFactory, EpsilonMode, Infer,
    InferResponse, McPrediction, MetricsSnapshot, ServeError, ShardSnapshot, SourceFactory,
    Ticket, UncertaintyReport,
};
use bnn_cim::coordinator::GrngBankSource;
use bnn_cim::data::SyntheticPerson;
use bnn_cim::runtime::{InferenceEngine, SimEngine};
use std::sync::Arc;
use std::time::Duration;

/// Every v1 entry point, frozen by signature.
#[test]
fn v1_signatures_compile() {
    let _builder: fn(Config) -> CoordinatorBuilder = Coordinator::builder;
    let _backend: fn(CoordinatorBuilder, Backend) -> CoordinatorBuilder =
        CoordinatorBuilder::backend;
    let _workers: fn(CoordinatorBuilder, usize) -> CoordinatorBuilder =
        CoordinatorBuilder::workers;
    let _mc_workers: fn(CoordinatorBuilder, usize) -> CoordinatorBuilder =
        CoordinatorBuilder::mc_workers;
    let _epsilon: fn(CoordinatorBuilder, EpsilonMode) -> CoordinatorBuilder =
        CoordinatorBuilder::epsilon;
    let _source: fn(CoordinatorBuilder, SourceFactory) -> CoordinatorBuilder =
        CoordinatorBuilder::source_factory;
    let _engine: fn(CoordinatorBuilder, EngineFactory) -> CoordinatorBuilder =
        CoordinatorBuilder::engine_factory;
    let _start: fn(CoordinatorBuilder) -> Result<Coordinator, ServeError> =
        CoordinatorBuilder::start;

    let _submit: fn(&Coordinator, Infer) -> Result<Ticket, ServeError> = Coordinator::submit;
    let _infer: fn(&Coordinator, Infer) -> Result<InferResponse, ServeError> =
        Coordinator::infer;
    let _metrics: fn(&Coordinator) -> MetricsSnapshot = Coordinator::metrics;
    let _pool_size: fn(&Coordinator) -> usize = Coordinator::workers;
    let _shutdown: fn(Coordinator) = Coordinator::shutdown;
    // `submit_many` is generic over its iterator; pin the monomorphic
    // Vec<Infer> shape.
    let _submit_many = |c: &Coordinator, v: Vec<Infer>| -> Result<Vec<Ticket>, ServeError> {
        c.submit_many(v)
    };

    let _new: fn(Vec<f32>) -> Infer = Infer::new;
    let _mc: fn(Infer, usize) -> Infer = Infer::mc_samples;
    let _thr: fn(Infer, f64) -> Infer = Infer::defer_threshold;

    let _wait: fn(Ticket) -> Result<InferResponse, ServeError> = Ticket::wait;
    let _wait_timeout: fn(&Ticket, Duration) -> Result<InferResponse, ServeError> =
        Ticket::wait_timeout;
    let _try_wait: fn(&Ticket) -> Result<Option<InferResponse>, ServeError> = Ticket::try_wait;
}

/// The v1 data types, frozen structurally: exhaustive destructuring
/// breaks this test when a public field is renamed, retyped, or removed.
#[test]
fn v1_data_types_are_structurally_pinned() {
    fn report_fields(u: UncertaintyReport) -> (f64, f64, f64, f64, bool) {
        let UncertaintyReport {
            entropy,
            aleatoric,
            epistemic,
            threshold,
            deferred,
        } = u;
        (entropy, aleatoric, epistemic, threshold, deferred)
    }
    fn response_fields(
        r: InferResponse,
    ) -> (u64, McPrediction, UncertaintyReport, Duration, u64, f64) {
        let InferResponse {
            id,
            pred,
            uncertainty,
            latency,
            batch_id,
            energy_j,
        } = r;
        (id, pred, uncertainty, latency, batch_id, energy_j)
    }
    let _ = report_fields as fn(_) -> _;
    let _ = response_fields as fn(_) -> _;
    let _deferred: fn(&InferResponse) -> bool = InferResponse::deferred;
    let _shard_orphans = |s: &ShardSnapshot| s.requests_orphaned;
    let _global_orphans = |m: &MetricsSnapshot| m.requests_orphaned;

    // ServeError: a std error with every v1 failure mode nameable.
    fn assert_error<E: std::error::Error + Send + Sync + 'static>() {}
    assert_error::<ServeError>();
    let _variants = [
        ServeError::QueueFull,
        ServeError::WrongShape { expected: 0, got: 0 },
        ServeError::McSamplesTooLarge { max: 0, got: 0 },
        ServeError::InvalidDeferThreshold { got: 0.0 },
        ServeError::ShuttingDown,
        ServeError::Timeout,
        ServeError::Disconnected,
        ServeError::ShardFailed { shard: 0 },
        ServeError::Config(String::new()),
        ServeError::Startup(String::new()),
    ];
}

fn sim_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.model.mc_samples = 4;
    cfg.server.batch_deadline_ms = 1.0;
    cfg
}

/// Serve a short serial workload and collect the probability vectors.
fn serve(coord: Coordinator) -> Vec<Vec<f64>> {
    let gen = SyntheticPerson::new(32, 1234);
    let out: Vec<Vec<f64>> = (0..4)
        .map(|i| {
            coord
                .infer(Infer::new(gen.sample(i).pixels))
                .unwrap()
                .pred
                .probs
        })
        .collect();
    coord.shutdown();
    out
}

#[test]
#[allow(deprecated)] // exercises the pre-v1 shims on purpose
fn deprecated_sim_constructors_are_builder_shims() {
    let via_builder = serve(
        Coordinator::builder(sim_cfg())
            .backend(Backend::Sim)
            .start()
            .unwrap(),
    );
    let via_start_sim = serve(Coordinator::start_sim(sim_cfg()).unwrap());
    assert_eq!(via_builder, via_start_sim, "start_sim must shim the builder");

    let mut cfg = sim_cfg();
    cfg.server.backend = Backend::Sim;
    let via_start_backend = serve(Coordinator::start_backend(cfg).unwrap());
    assert_eq!(via_builder, via_start_backend, "start_backend must shim the builder");

    // start_with: explicit engine factory + external ε supply.
    let cfg = sim_cfg();
    let engine_cfg = cfg.clone();
    let factory: EngineFactory = Arc::new(move |_shard| {
        Ok(Box::new(SimEngine::from_config(&engine_cfg)) as Box<dyn InferenceEngine>)
    });
    let via_start_with = serve(
        Coordinator::start_with(
            cfg.clone(),
            factory,
            bnn_cim::coordinator::EpsilonSupply::External(GrngBankSource::shard_factory(
                &cfg.chip,
            )),
        )
        .unwrap(),
    );
    assert_eq!(via_builder, via_start_with, "start_with must shim the builder");
}

#[test]
#[allow(deprecated)] // exercises the pre-v1 shims on purpose
fn deprecated_cim_constructor_is_a_builder_shim() {
    // Small tiles keep bring-up calibration cheap in debug builds.
    let mk = || {
        let mut cfg = sim_cfg();
        cfg.chip.tile.rows = 16;
        cfg.chip.tile.words_per_row = 4;
        cfg
    };
    let via_builder = serve(
        Coordinator::builder(mk())
            .backend(Backend::Cim)
            .start()
            .unwrap(),
    );
    let via_start_cim = serve(Coordinator::start_cim(mk()).unwrap());
    assert_eq!(via_builder, via_start_cim, "start_cim must shim the builder");
}

#[test]
#[allow(deprecated)] // exercises the pre-v1 shims on purpose
fn deprecated_infer_blocking_is_an_infer_shim() {
    let gen = SyntheticPerson::new(32, 9);
    let old = {
        let coord = Coordinator::builder(sim_cfg())
            .backend(Backend::Sim)
            .start()
            .unwrap();
        let resp = coord.infer_blocking(gen.sample(0).pixels, 3).unwrap();
        coord.shutdown();
        resp.pred.probs
    };
    let new = {
        let coord = Coordinator::builder(sim_cfg())
            .backend(Backend::Sim)
            .start()
            .unwrap();
        let resp = coord
            .infer(Infer::new(gen.sample(0).pixels).mc_samples(3))
            .unwrap();
        coord.shutdown();
        resp.pred.probs
    };
    assert_eq!(old, new, "infer_blocking must shim infer(Infer…)");
}

#[cfg(not(feature = "pjrt"))]
#[test]
#[allow(deprecated)] // start/start_with_source are pre-v1 shims
fn pjrt_constructors_error_cleanly_without_the_feature() {
    use bnn_cim::coordinator::PhiloxSource;
    // Builder and shims agree: booting the pjrt backend without the
    // feature is a startup error, not a panic.
    let err = Coordinator::builder(sim_cfg())
        .backend(Backend::Pjrt)
        .start()
        .unwrap_err();
    assert!(matches!(err, ServeError::Startup(_)));
    assert!(Coordinator::start(sim_cfg()).is_err());
    assert!(Coordinator::start_with_source(sim_cfg(), PhiloxSource::shard_factory(1)).is_err());
}
