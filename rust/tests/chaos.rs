//! Chaos acceptance (ISSUE 8): supervised shards under deterministic
//! fault injection.
//!
//! 1. Kill a shard worker mid-flight under load: the supervisor respawns
//!    it and **every** submitted ticket resolves — as a response or a
//!    typed error — with zero lost (hung/Disconnected) tickets.
//! 2. Fault replay is deterministic: two pools under the same ε-corruption
//!    plan produce bit-identical responses, and both differ from a clean
//!    pool (the injected SEU flips really perturb the
//!    `UncertaintyReport`).
//! 3. A dead shard (restart limit exhausted) fails blocked waits
//!    *promptly* with `ServeError::ShardFailed` — well under the request
//!    timeout — and an all-dead pool fails new submissions fast too.
//!
//! Everything runs on the deterministic `SimEngine`. The crash test
//! optionally emits a conservation report (`BNN_CIM_CHAOS_REPORT=path`)
//! that `scripts/bench_gate.py` audits in CI's chaos-smoke job.

use bnn_cim::client::{Backend, Config, Coordinator, FaultPlan, Infer, ServeError, ShardHealth};
use bnn_cim::data::SyntheticPerson;
use std::time::{Duration, Instant};

fn chaos_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.model.mc_samples = 4;
    cfg.server.batch_deadline_ms = 1.0;
    cfg.server.request_timeout_ms = 30_000.0;
    cfg
}

/// Kill-mid-flight under load: with the panic armed on every shard's
/// first engine incarnation, both workers of a 2-shard pool die while
/// requests are in flight. The supervisor must respawn them (original
/// seed splits) and redeliver the recovered batches under the retry
/// budget, so every ticket resolves — response or typed error — with
/// nothing hung and nothing Disconnected.
#[test]
fn killed_workers_are_respawned_and_no_ticket_is_lost() {
    let mut cfg = chaos_cfg();
    cfg.server.retry_budget = 2;
    let coord = Coordinator::builder(cfg)
        .backend(Backend::Sim)
        .workers(2)
        .fault_plan(FaultPlan {
            seed: 7,
            panic_at_run: 5,
            ..FaultPlan::default()
        })
        .start()
        .unwrap();

    let n: u64 = 40;
    let gen = SyntheticPerson::new(32, 21);
    let tickets = coord
        .submit_many((0..n).map(|i| Infer::new(gen.sample(i).pixels)))
        .unwrap();

    let (mut completed, mut failed_typed) = (0u64, 0u64);
    for t in tickets {
        match t.wait_timeout(Duration::from_secs(30)) {
            Ok(_) => completed += 1,
            Err(ServeError::ShardFailed { .. }) => failed_typed += 1,
            Err(other) => panic!("ticket lost to an untyped failure: {other}"),
        }
    }
    assert_eq!(
        completed + failed_typed,
        n,
        "conservation: every submitted ticket must resolve"
    );

    let m = coord.metrics();
    assert!(
        m.shard_restarts >= 1,
        "the armed panic must have killed at least one worker (restarts = {})",
        m.shard_restarts
    );
    assert!(
        m.requests_retried >= 1,
        "recovered in-flight requests must be redelivered (retried = {})",
        m.requests_retried
    );
    // Both shards recovered: the pool ends fully healthy.
    assert_eq!(coord.healthy_workers(), 2);
    assert!(coord.shard_health().iter().all(|h| *h == ShardHealth::Healthy));
    // Per-shard counters sum to the global ones.
    let per_restarts: u64 = m.per_shard.iter().map(|s| s.shard_restarts).sum();
    let per_retried: u64 = m.per_shard.iter().map(|s| s.requests_retried).sum();
    assert_eq!(per_restarts, m.shard_restarts);
    assert_eq!(per_retried, m.requests_retried);

    if let Ok(path) = std::env::var("BNN_CIM_CHAOS_REPORT") {
        let report = format!(
            "{{\n  \"source\": \"tests/chaos.rs killed_workers_are_respawned_and_no_ticket_is_lost\",\n  \
               \"suite\": \"chaos\",\n  \
               \"submitted\": {n},\n  \
               \"completed\": {completed},\n  \
               \"failed_typed\": {failed_typed},\n  \
               \"shard_restarts\": {},\n  \
               \"requests_retried\": {}\n}}\n",
            m.shard_restarts, m.requests_retried
        );
        std::fs::write(&path, report).unwrap();
        eprintln!("chaos report written to {path}");
    }

    coord.shutdown();
}

/// Fault replay: the chaos stream is part of the determinism contract.
/// Two pools under the same ε-corruption plan must produce bit-identical
/// responses for a serial workload, and both must differ from a clean
/// pool — the SEU bit flips and the ADC offset step really reach the
/// Bayesian head and perturb its `UncertaintyReport`.
#[test]
fn fault_replay_is_bit_identical_and_perturbs_uncertainty() {
    let run = |plan: FaultPlan| {
        let coord = Coordinator::builder(chaos_cfg())
            .backend(Backend::Sim)
            .fault_plan(plan)
            .start()
            .unwrap();
        let gen = SyntheticPerson::new(32, 9);
        let mut out = Vec::new();
        for i in 0..5 {
            let resp = coord.infer(Infer::new(gen.sample(i).pixels)).unwrap();
            out.push((resp.pred.probs.clone(), resp.uncertainty.entropy));
        }
        coord.shutdown();
        out
    };
    let corrupt = FaultPlan {
        seed: 42,
        eps_bit_flips: 2,
        adc_offset_step: 0.5,
        ..FaultPlan::default()
    };
    let a = run(corrupt.clone());
    let b = run(corrupt);
    // `FaultPlan::default()` explicitly disables injection, so the clean
    // pool is immune to any ambient BNN_CIM_FAULT_PLAN (CI sweeps).
    let clean = run(FaultPlan::default());
    assert_eq!(a, b, "same fault plan must replay bit-identically");
    assert_ne!(a, clean, "ε corruption must perturb the posterior");
    let entropy_moved = a.iter().zip(&clean).any(|(f, c)| f.1 != c.1);
    assert!(entropy_moved, "entropy must move under ε corruption");
}

/// Respawn fidelity on the chip backend: a shard worker killed mid-serve
/// is rebuilt through the engine factory's `SharedModelCache` — cloning
/// the cached calibrated model (Arc-sharing its weight/calibration
/// layer) instead of re-running bring-up — and must serve **bit-
/// identically** to a freshly booted pool. The crash lands inside
/// request 1's serve, so the respawned engine (boot-time streams)
/// re-serves request 1 exactly as a cold boot would, and every later
/// response continues that stream.
#[test]
fn respawned_cim_shard_replays_bit_identically_to_fresh_boot() {
    let mut cfg = chaos_cfg();
    cfg.server.retry_budget = 2;
    // Small tiles keep cim bring-up cheap in debug builds; max_batch = 1
    // keeps the workload serial (one request per batch).
    cfg.chip.tile.rows = 16;
    cfg.chip.tile.words_per_row = 4;
    cfg.server.max_batch = 1;
    let gen = SyntheticPerson::new(32, 33);

    // Pool A: the armed panic kills the worker during request 1's serve;
    // the supervisor respawns it from the model cache and redelivers.
    let faulty = Coordinator::builder(cfg.clone())
        .backend(Backend::Cim)
        .fault_plan(FaultPlan {
            seed: 5,
            panic_at_run: 3,
            ..FaultPlan::default()
        })
        .start()
        .unwrap();
    let r1 = faulty.infer(Infer::new(gen.sample(0).pixels)).unwrap();
    let r2 = faulty.infer(Infer::new(gen.sample(1).pixels)).unwrap();
    let m = faulty.metrics();
    assert!(
        m.shard_restarts >= 1,
        "the armed panic must have forced a respawn (restarts = {})",
        m.shard_restarts
    );
    faulty.shutdown();

    // Pool B: clean cold boot, same config and workload. The respawned
    // shard restarted its deterministic streams, so A's responses must
    // match B's byte for byte.
    let fresh = Coordinator::builder(cfg)
        .backend(Backend::Cim)
        .fault_plan(FaultPlan::default())
        .start()
        .unwrap();
    let f1 = fresh.infer(Infer::new(gen.sample(0).pixels)).unwrap();
    let f2 = fresh.infer(Infer::new(gen.sample(1).pixels)).unwrap();
    fresh.shutdown();

    assert_eq!(r1.pred.probs, f1.pred.probs, "respawn must replay boot streams");
    assert_eq!(r1.uncertainty.entropy, f1.uncertainty.entropy);
    assert_eq!(r2.pred.probs, f2.pred.probs, "post-respawn stream must continue");
    assert_eq!(r2.uncertainty.entropy, f2.uncertainty.entropy);
}

/// Failure is *delivered*, not discovered by timeout: with respawns
/// disabled and no retry budget, a worker panic turns every affected wait
/// into a prompt `ShardFailed` — orders of magnitude before the 30 s
/// request timeout — the shard reports `dead`, and an all-dead pool fails
/// fresh submissions just as fast.
#[test]
fn dead_shard_fails_waits_promptly_and_all_dead_pool_fails_fast() {
    let mut cfg = chaos_cfg();
    cfg.server.retry_budget = 0;
    cfg.server.shard_restart_limit = 0;
    let coord = Coordinator::builder(cfg)
        .backend(Backend::Sim)
        .workers(1)
        .fault_plan(FaultPlan {
            seed: 3,
            panic_at_run: 1,
            ..FaultPlan::default()
        })
        .start()
        .unwrap();

    let gen = SyntheticPerson::new(32, 17);
    let tickets = coord
        .submit_many((0..3).map(|i| Infer::new(gen.sample(i).pixels)))
        .unwrap();
    let t0 = Instant::now();
    for t in tickets {
        match t.wait() {
            Err(ServeError::ShardFailed { shard: 0 }) => {}
            other => panic!("expected ShardFailed from shard 0, got {other:?}"),
        }
    }
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_secs(10),
        "typed failure took {elapsed:?} — waits must not run out the 30 s deadline"
    );

    // The supervisor has already marked the shard dead (waits resolved
    // *after* recovery), so the health surface is settled.
    assert_eq!(coord.shard_health(), vec![ShardHealth::Dead]);
    assert_eq!(coord.healthy_workers(), 0);
    assert!(coord.all_shards_dead());
    let m = coord.metrics();
    assert_eq!(m.shard_restarts, 0, "shard_restart_limit = 0: no respawn");
    assert!(m.requests_failed_shard >= 1);

    // New submissions are admitted (the queue is open) but fail fast and
    // typed at dispatch — not by timeout.
    let t0 = Instant::now();
    let ticket = coord.submit(Infer::new(gen.sample(99).pixels)).unwrap();
    match ticket.wait() {
        Err(ServeError::ShardFailed { .. }) => {}
        other => panic!("expected ShardFailed on an all-dead pool, got {other:?}"),
    }
    assert!(t0.elapsed() < Duration::from_secs(10));

    coord.shutdown();
}
