//! Artifact-free serving smoke (CI "backend-smoke" job): boot the
//! coordinator on each artifact-free backend (`sim` and `cim`), submit a
//! small batch, and assert a nonzero energy counter in the metrics
//! snapshot — the end-to-end path a fresh checkout must always serve.
//!
//! Also seeds the repo-root `BENCH_serving.json` with a smoke-scale
//! sim-vs-cim throughput sweep, so every `cargo test` leaves a
//! machine-readable perf artifact behind;
//! `cargo bench --bench sharded_serving` overwrites it with calibrated
//! release-profile numbers.

use bnn_cim::client::{Backend, Config, Coordinator, Infer};
use bnn_cim::data::SyntheticPerson;
use bnn_cim::util::bench::{
    is_calibrated_report, measure_serving_sweep, repo_root_artifact, Suite,
};
use bnn_cim::util::json::Json;
use std::sync::Mutex;
use std::time::Duration;

/// Serialize the smoke tests within this binary: the sweep times
/// throughput, so concurrent pool boot-up / tile calibration from the
/// sibling tests would distort the numbers written to BENCH_serving.json.
static SERIAL: Mutex<()> = Mutex::new(());

fn smoke_cfg(backend: Backend) -> Config {
    let mut cfg = Config::default();
    cfg.server.backend = backend;
    cfg.server.workers = 2;
    cfg.model.mc_samples = 4;
    cfg.server.batch_deadline_ms = 2.0;
    // Small tiles keep cim bring-up calibration cheap in debug builds.
    cfg.chip.tile.rows = 16;
    cfg.chip.tile.words_per_row = 4;
    cfg
}

fn serve_small_batch(backend: Backend) -> bnn_cim::client::MetricsSnapshot {
    let cfg = smoke_cfg(backend);
    let coord = Coordinator::builder(cfg.clone())
        .start()
        .unwrap_or_else(|e| panic!("boot {} backend: {e}", backend.name()));
    let gen = SyntheticPerson::new(cfg.model.image_side, 99);
    let tickets = coord
        .submit_many((0..8).map(|i| Infer::new(gen.sample(i).pixels)))
        .unwrap();
    for ticket in tickets {
        let resp = ticket.wait_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(resp.pred.probs.len(), cfg.model.classes);
        assert!((resp.pred.probs.iter().sum::<f64>() - 1.0).abs() < 1e-5);
    }
    let m = coord.metrics();
    coord.shutdown();
    m
}

#[test]
fn sim_backend_smoke_has_nonzero_epsilon_energy() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let m = serve_small_batch(Backend::Sim);
    assert_eq!(m.requests_total, 8);
    assert!(m.epsilon_samples > 0, "sim backend drew no ε");
    assert!(
        m.epsilon_energy_j > 0.0,
        "per-shard GRNG-bank sources must meter ε energy"
    );
}

#[test]
fn cim_backend_smoke_has_nonzero_tile_and_epsilon_energy() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let m = serve_small_batch(Backend::Cim);
    assert_eq!(m.requests_total, 8);
    assert!(m.epsilon_samples > 0, "in-word banks drew no ε");
    assert!(m.epsilon_energy_j > 0.0, "ε energy counter is zero");
    assert!(
        m.engine_energy_j > 0.0,
        "tile EnergyLedgers must surface into the snapshot"
    );
    assert!(m.epsilon_fj_per_sample() > 0.0);
    assert!(m.engine_j_per_op() > 0.0);
}

/// Emit the repo-root `BENCH_serving.json` sweep (sim vs cim × two worker
/// counts) so `cargo test` always leaves the perf artifact behind. The
/// numbers are a smoke-scale *seed* (test profile; other test binaries
/// may run concurrently — the SERIAL mutex only quiets this binary), so
/// the report marks itself "smoke" and yields to any calibrated bench run.
#[test]
fn emit_bench_serving_json_smoke_sweep() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let root = repo_root_artifact("BENCH_serving.json");
    // A calibrated release-profile report from the bench takes precedence
    // over this smoke-scale seed — check before measuring anything so
    // repeated test runs skip the (slow, test-profile) cim sweep.
    if is_calibrated_report(&root) {
        eprintln!("keeping calibrated {}", root.display());
        return;
    }
    let mut sweeps: Vec<Json> = Vec::new();
    for &backend in &[Backend::Sim, Backend::Cim] {
        for &workers in &[1usize, 2] {
            let mut cfg = smoke_cfg(backend);
            cfg.server.workers = workers;
            cfg.server.batch_deadline_ms = 0.5;
            let point = measure_serving_sweep(&cfg, 24);
            assert!(point.req_per_s > 0.0);
            sweeps.push(point.to_json());
        }
    }
    // Same writer as the bench (shared envelope); the "smoke" marker in
    // `source` is what lets the calibrated report take precedence.
    let src_note = "tests/backend_smoke.rs smoke sweep (test profile); run \
                    `cargo bench --bench sharded_serving` for calibrated numbers";
    let suite = Suite::new("sharded_serving (sim vs cim smoke sweep)");
    suite.write_report(
        &root,
        vec![
            ("source", Json::Str(src_note.to_string())),
            ("sweeps", Json::Arr(sweeps)),
        ],
    );
    assert!(root.exists(), "BENCH_serving.json must be written");
}
