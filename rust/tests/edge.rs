//! Network-edge integration tests: the wire contract end to end.
//!
//! Three layers of guarantees, each pinned here:
//!
//! 1. **Codec** — every float in a wire response round-trips
//!    bit-identically (`util::json::write_number` shortest form), and the
//!    lazy request scanner agrees with the tree parser on every valid
//!    body while rejecting (never panicking on) malformed ones.
//! 2. **Transport** — malformed bodies become HTTP 400 over a live
//!    socket and the server keeps serving; the `ServeError` → status
//!    taxonomy is fixed.
//! 3. **Semantics** — a wire `POST /v1/infer` response is bit-identical
//!    to the in-process `Ticket::wait` result for a fixed
//!    `(die_seed, workers, mc_workers)` triple, and under overload the
//!    shed/degrade/escalate machine visibly engages (nonzero counters,
//!    bounded latency).

use bnn_cim::bayes::{McPrediction, UncertaintyReport};
use bnn_cim::client::{Backend, Config, Coordinator, EdgeServer, Infer, InferResponse, ServeError};
use bnn_cim::data::SyntheticPerson;
use bnn_cim::edge::json::{error_json, infer_batch_json, infer_response_json};
use bnn_cim::edge::{scan_infer_batch, status_for, Disposition, MiniClient};
use bnn_cim::runtime::{InferenceEngine, Manifest, SimEngine};
use bnn_cim::util::json::Json;
use bnn_cim::util::propcheck::property;
use std::sync::Arc;
use std::time::{Duration, Instant};

const CLIENT_TIMEOUT: Duration = Duration::from_secs(60);

// ---------------------------------------------------------------------
// 1. Codec
// ---------------------------------------------------------------------

/// A response stuffed with awkward floats: values whose decimal
/// representation is not exact, subnormals, huge magnitudes, and a
/// one-ULP neighbor of ln 2 that naive formatting would collapse.
fn awkward_response() -> InferResponse {
    let ln2_plus_ulp = f64::from_bits(std::f64::consts::LN_2.to_bits() + 1);
    InferResponse {
        id: 7,
        pred: McPrediction {
            probs: vec![0.1, 1.0 / 3.0, 2.0f64.powi(-1074), 1e300, 0.3f64 + 0.2],
            entropy: ln2_plus_ulp,
            expected_entropy: 1e-17,
            mutual_information: 0.1 + 0.2,
            class: 1,
            confidence: 1.0 / 7.0,
            t: 12,
        },
        uncertainty: UncertaintyReport {
            entropy: ln2_plus_ulp,
            aleatoric: 1e-17,
            epistemic: 0.1 + 0.2,
            threshold: 0.45000000000000001,
            deferred: true,
        },
        latency: Duration::from_micros(12345),
        batch_id: 3,
        energy_j: 3.6e-13,
    }
}

#[test]
fn wire_response_floats_round_trip_bit_identically() {
    let resp = awkward_response();
    let disp = Disposition {
        degraded: true,
        escalated: false,
    };
    let body = infer_response_json(&resp, disp);
    let doc = Json::parse(&body).expect("wire response must be valid JSON");

    let bits = |v: Option<&Json>| v.and_then(Json::as_f64).map(f64::to_bits);
    let probs = doc.get("probs").and_then(Json::as_f64_vec).unwrap();
    assert_eq!(probs.len(), resp.pred.probs.len());
    for (wire, orig) in probs.iter().zip(&resp.pred.probs) {
        assert_eq!(wire.to_bits(), orig.to_bits(), "probs lost bits");
    }
    assert_eq!(
        bits(doc.get("confidence")),
        Some(resp.pred.confidence.to_bits())
    );
    let u = doc.get("uncertainty").expect("uncertainty object");
    assert_eq!(bits(u.get("entropy")), Some(resp.uncertainty.entropy.to_bits()));
    assert_eq!(
        bits(u.get("aleatoric")),
        Some(resp.uncertainty.aleatoric.to_bits())
    );
    assert_eq!(
        bits(u.get("epistemic")),
        Some(resp.uncertainty.epistemic.to_bits())
    );
    assert_eq!(
        bits(u.get("threshold")),
        Some(resp.uncertainty.threshold.to_bits())
    );
    assert_eq!(u.get("deferred").and_then(Json::as_bool), Some(true));
    assert_eq!(bits(doc.get("energy_j")), Some(resp.energy_j.to_bits()));
    assert_eq!(doc.get("id").and_then(Json::as_f64), Some(7.0));
    assert_eq!(doc.get("class").and_then(Json::as_f64), Some(1.0));
    assert_eq!(doc.get("mc_samples").and_then(Json::as_f64), Some(12.0));
    assert_eq!(doc.get("degraded").and_then(Json::as_bool), Some(true));
    assert_eq!(doc.get("escalated").and_then(Json::as_bool), Some(false));

    // Batch shape wraps the same objects.
    let batch = infer_batch_json(&[(resp.clone(), disp), (resp, Disposition::default())]);
    let doc = Json::parse(&batch).unwrap();
    let items = doc.get("responses").and_then(Json::as_arr).unwrap();
    assert_eq!(items.len(), 2);
    assert_eq!(items[1].get("degraded").and_then(Json::as_bool), Some(false));

    // Non-finite energy must degrade to null, not invalid JSON.
    let mut nan = awkward_response();
    nan.energy_j = f64::NAN;
    let doc = Json::parse(&infer_response_json(&nan, Disposition::default())).unwrap();
    assert!(matches!(doc.get("energy_j"), Some(Json::Null)));

    // Error bodies parse and carry the retry hint.
    let doc = Json::parse(&error_json("shed", "overloaded \"now\"\n", Some(250))).unwrap();
    let err = doc.get("error").unwrap();
    assert_eq!(err.get("kind").and_then(Json::as_str), Some("shed"));
    assert_eq!(err.get("retry_after_ms").and_then(Json::as_f64), Some(250.0));
}

#[test]
fn scanner_agrees_with_tree_parser() {
    property("scan matches tree parse", 150, |g| {
        let pixels = g.vec_f32_nonempty(48, -16.0, 16.0);
        let mc = g.usize_in(0, 300);
        let threshold = if g.bool() {
            Some(g.f64_in(0.0, 10.0))
        } else {
            None
        };
        let n_reqs = g.usize_in(1, 4);
        let batch = g.bool();

        let mut one = String::from("{\"junk\":{\"a\":[1,{\"b\":\"}]\\\"\"},null,[]],\"c\":true},");
        one.push_str("\"pixels\":[");
        for (i, p) in pixels.iter().enumerate() {
            if i > 0 {
                one.push(',');
            }
            one.push_str(&format!("{p}"));
        }
        one.push(']');
        if mc > 0 {
            one.push_str(&format!(",\"mc_samples\":{mc}"));
        }
        if let Some(t) = threshold {
            one.push_str(&format!(",\"defer_threshold\":{t}"));
        }
        one.push('}');

        let body = if batch {
            let mut b = String::from("{\"requests\":[");
            for i in 0..n_reqs {
                if i > 0 {
                    b.push(',');
                }
                b.push_str(&one);
            }
            b.push_str("]}");
            b
        } else {
            one.clone()
        };

        let (reqs, was_batch) = scan_infer_batch(body.as_bytes()).expect("valid body");
        assert_eq!(was_batch, batch);
        assert_eq!(reqs.len(), if batch { n_reqs } else { 1 });
        for r in &reqs {
            assert_eq!(r.pixels.len(), pixels.len());
            for (got, want) in r.pixels.iter().zip(&pixels) {
                // Shortest-form f32 text through the f64 scanner must land
                // back on the same f32 bits.
                assert_eq!(got.to_bits(), want.to_bits(), "pixel lost bits");
            }
            assert_eq!(r.mc_samples, mc);
            assert_eq!(
                r.defer_threshold.map(f64::to_bits),
                threshold.map(f64::to_bits)
            );
        }

        // The strict tree parser accepts the same body and agrees on
        // pixels (scanner is a projection, not a different grammar).
        let tree = Json::parse(&body).expect("tree parser agrees body is valid");
        let obj = if batch {
            &tree.get("requests").and_then(Json::as_arr).unwrap()[0]
        } else {
            &tree
        };
        let tree_pixels = obj.get("pixels").and_then(Json::as_f32_vec).unwrap();
        assert_eq!(tree_pixels.len(), reqs[0].pixels.len());
    });
}

#[test]
fn scanner_never_panics_on_hostile_bytes() {
    // Pure random bytes: any outcome but a panic.
    property("random bytes never panic the scanner", 300, |g| {
        let n = g.usize_in(0, 256);
        let bytes: Vec<u8> = (0..n).map(|_| g.usize_in(0, 255) as u8).collect();
        let _ = scan_infer_batch(&bytes);
    });
    // Truncations and single-byte corruptions of a valid body: the
    // harder adversary, because prefixes are nearly-well-formed.
    let valid = br#"{"requests":[{"pixels":[0.5,-1.25,3e-2],"mc_samples":8,"defer_threshold":0.4,"x":{"y":[1,"}"]}}]}"#;
    property("mutated valid bodies never panic", 300, |g| {
        let mut b = valid.to_vec();
        if g.bool() {
            b.truncate(g.usize_in(0, b.len()));
        } else {
            let i = g.usize_in(0, b.len() - 1);
            b[i] = g.usize_in(0, 255) as u8;
        }
        let _ = scan_infer_batch(&b);
    });
}

// ---------------------------------------------------------------------
// 2. Transport + taxonomy
// ---------------------------------------------------------------------

#[test]
fn serve_error_status_taxonomy_is_fixed() {
    assert_eq!(status_for(&ServeError::QueueFull), 429);
    assert_eq!(
        status_for(&ServeError::WrongShape {
            expected: 1024,
            got: 3
        }),
        400
    );
    assert_eq!(
        status_for(&ServeError::McSamplesTooLarge { max: 256, got: 999 }),
        400
    );
    assert_eq!(
        status_for(&ServeError::InvalidDeferThreshold { got: f64::NAN }),
        400
    );
    assert_eq!(status_for(&ServeError::ShuttingDown), 503);
    assert_eq!(status_for(&ServeError::Timeout), 504);
    assert_eq!(status_for(&ServeError::Disconnected), 502);
    assert_eq!(status_for(&ServeError::ShardFailed { shard: 0 }), 502);
    assert_eq!(status_for(&ServeError::Config("x".into())), 500);
    assert_eq!(status_for(&ServeError::Startup("x".into())), 500);
}

fn edge_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.server.backend = Backend::Sim;
    cfg.server.workers = 2;
    cfg.server.mc_workers = 1;
    cfg.model.mc_samples = 4;
    cfg.server.request_timeout_ms = 30_000.0;
    cfg
}

fn pixels_json(pixels: &[f32]) -> String {
    let mut s = String::from("[");
    for (i, p) in pixels.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("{p}"));
    }
    s.push(']');
    s
}

#[test]
fn edge_http_surface_serves_and_survives_malformed() {
    let cfg = edge_cfg();
    let coord = Arc::new(Coordinator::builder(cfg.clone()).start().unwrap());
    let edge = EdgeServer::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let mut client = MiniClient::connect(edge.local_addr(), CLIENT_TIMEOUT).unwrap();

    // Liveness and routing.
    let (status, body) = client.request("GET", "/v1/health", None).unwrap();
    assert_eq!(status, 200);
    let doc = Json::parse(&body).unwrap();
    assert_eq!(doc.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(doc.get("backend").and_then(Json::as_str), Some("sim"));
    assert_eq!(doc.get("workers").and_then(Json::as_f64), Some(2.0));
    let (status, _) = client.request("GET", "/v1/nope", None).unwrap();
    assert_eq!(status, 404);
    let (status, _) = client.request("GET", "/v1/infer", None).unwrap();
    assert_eq!(status, 405);

    // Malformed bodies: 400 each, connection and server stay healthy.
    let mut deep = String::from(r#"{"pixels":[1],"junk":"#);
    deep.push_str(&"[".repeat(100_000));
    deep.push('}');
    for bad in [
        "{",
        "null",
        r#"{"mc_samples":4}"#,
        r#"{"pixels":[1,]}"#,
        r#"{"pixels":[1]}trailing"#,
        r#"{"requests":[]}"#,
        deep.as_str(),
    ] {
        let (status, body) = client.request("POST", "/v1/infer", Some(bad)).unwrap();
        assert_eq!(status, 400, "body {bad:.40} must be rejected");
        let doc = Json::parse(&body).unwrap();
        assert_eq!(
            doc.get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
            Some("bad_request")
        );
    }

    // Well-formed JSON that fails admission validation: 400 with the
    // specific taxonomy kind, not a generic parse error.
    let (status, body) = client
        .request("POST", "/v1/infer", Some(r#"{"pixels":[1,2,3]}"#))
        .unwrap();
    assert_eq!(status, 400);
    assert!(body.contains("wrong_shape"), "got {body}");
    let big = format!(
        "{{\"pixels\":{},\"mc_samples\":99999}}",
        pixels_json(&vec![0.0; cfg.model.image_side * cfg.model.image_side])
    );
    let (status, body) = client.request("POST", "/v1/infer", Some(&big)).unwrap();
    assert_eq!(status, 400);
    assert!(body.contains("mc_samples_too_large"), "got {body}");

    // The same connection still serves a valid request...
    let person = SyntheticPerson::new(cfg.model.image_side, 42).sample(0);
    let good = format!("{{\"pixels\":{}}}", pixels_json(&person.pixels));
    let (status, body) = client.request("POST", "/v1/infer", Some(&good)).unwrap();
    assert_eq!(status, 200, "got {body}");
    let doc = Json::parse(&body).unwrap();
    assert!(doc.get("uncertainty").is_some());
    assert_eq!(doc.get("mc_samples").and_then(Json::as_f64), Some(4.0));

    // ...and the metrics route reports it, per shard and globally.
    let (status, body) = client.request("GET", "/v1/metrics", None).unwrap();
    assert_eq!(status, 200);
    let doc = Json::parse(&body).unwrap();
    assert!(doc.get("requests_total").and_then(Json::as_f64).unwrap() >= 1.0);
    assert_eq!(doc.get("per_shard").and_then(Json::as_arr).unwrap().len(), 2);
    let render = doc.get("render").and_then(Json::as_str).unwrap();
    assert!(render.contains("edge shed="), "render: {render}");

    edge.shutdown();
    drop(coord); // Drop shuts the pool down
}

// ---------------------------------------------------------------------
// 3. Semantics: bit-identity and the admission machine
// ---------------------------------------------------------------------

#[test]
fn wire_infer_is_bit_identical_to_in_process() {
    let cfg = edge_cfg();
    let gen = SyntheticPerson::new(cfg.model.image_side, 7);
    let samples: Vec<Vec<f32>> = (0..3).map(|i| gen.sample(i).pixels).collect();

    // Reference: an in-process pool serving the same serial workload.
    let coord = Coordinator::builder(cfg.clone()).start().unwrap();
    let tickets = coord
        .submit_many(vec![
            Infer::new(samples[0].clone()).mc_samples(8),
            Infer::new(samples[1].clone()),
            Infer::new(samples[2].clone()).mc_samples(8).defer_threshold(0.45),
        ])
        .unwrap();
    let reference: Vec<InferResponse> = tickets
        .into_iter()
        .map(|t| t.wait_timeout(Duration::from_secs(120)).unwrap())
        .collect();
    coord.shutdown();

    // A fresh, identically-configured pool behind the HTTP edge. Same
    // (die_seed, workers, mc_workers) triple => the determinism contract
    // says the wire must not move a single bit.
    let coord = Arc::new(Coordinator::builder(cfg.clone()).start().unwrap());
    let edge = EdgeServer::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let mut client = MiniClient::connect(edge.local_addr(), CLIENT_TIMEOUT).unwrap();
    let body = format!(
        "{{\"requests\":[{{\"pixels\":{},\"mc_samples\":8}},{{\"pixels\":{}}},\
         {{\"pixels\":{},\"mc_samples\":8,\"defer_threshold\":0.45}}]}}",
        pixels_json(&samples[0]),
        pixels_json(&samples[1]),
        pixels_json(&samples[2]),
    );
    let (status, resp) = client.request("POST", "/v1/infer", Some(&body)).unwrap();
    assert_eq!(status, 200, "got {resp}");
    let doc = Json::parse(&resp).unwrap();
    let wire = doc.get("responses").and_then(Json::as_arr).unwrap();
    assert_eq!(wire.len(), reference.len());

    for (w, r) in wire.iter().zip(&reference) {
        let probs = w.get("probs").and_then(Json::as_f64_vec).unwrap();
        assert_eq!(probs.len(), r.pred.probs.len());
        for (a, b) in probs.iter().zip(&r.pred.probs) {
            assert_eq!(a.to_bits(), b.to_bits(), "probs moved over the wire");
        }
        let bits = |v: Option<&Json>| v.and_then(Json::as_f64).map(f64::to_bits);
        assert_eq!(
            bits(w.get("confidence")),
            Some(r.pred.confidence.to_bits())
        );
        let u = w.get("uncertainty").unwrap();
        assert_eq!(bits(u.get("entropy")), Some(r.uncertainty.entropy.to_bits()));
        assert_eq!(
            bits(u.get("aleatoric")),
            Some(r.uncertainty.aleatoric.to_bits())
        );
        assert_eq!(
            bits(u.get("epistemic")),
            Some(r.uncertainty.epistemic.to_bits())
        );
        assert_eq!(
            bits(u.get("threshold")),
            Some(r.uncertainty.threshold.to_bits())
        );
        assert_eq!(
            u.get("deferred").and_then(Json::as_bool),
            Some(r.uncertainty.deferred)
        );
        assert_eq!(
            w.get("class").and_then(Json::as_f64),
            Some(r.pred.class as f64)
        );
        assert_eq!(
            w.get("mc_samples").and_then(Json::as_f64),
            Some(r.pred.t as f64)
        );
        assert_eq!(w.get("degraded").and_then(Json::as_bool), Some(false));
        assert_eq!(w.get("escalated").and_then(Json::as_bool), Some(false));
    }

    edge.shutdown();
    drop(coord); // Drop shuts the pool down
}

/// An all-dead backend is a *service*-level condition on the wire: after
/// the lone shard dies with respawns disabled, in-flight requests come
/// back as per-request 502 `shard_failed`, `/v1/health` reports
/// `unhealthy` with the shard labelled `dead`, and fresh `POST /v1/infer`
/// calls are answered 503 `unhealthy` + `Retry-After` up front — not a
/// 502 per request.
#[test]
fn all_dead_backend_answers_503_with_retry_after() {
    use bnn_cim::client::FaultPlan;
    let mut cfg = edge_cfg();
    cfg.server.workers = 1;
    cfg.server.retry_budget = 0;
    cfg.server.shard_restart_limit = 0;
    let coord = Arc::new(
        Coordinator::builder(cfg.clone())
            .fault_plan(FaultPlan {
                seed: 5,
                panic_at_run: 1,
                ..FaultPlan::default()
            })
            .start()
            .unwrap(),
    );
    let edge = EdgeServer::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let mut client = MiniClient::connect(edge.local_addr(), CLIENT_TIMEOUT).unwrap();

    let person = SyntheticPerson::new(cfg.model.image_side, 23).sample(0);
    let body = format!("{{\"pixels\":{}}}", pixels_json(&person.pixels));

    // First request rides into the crash: by the time its typed failure
    // is delivered the supervisor has already marked the shard dead, so
    // this is a per-request 502 with the shard_failed kind.
    let (status, resp) = client.request("POST", "/v1/infer", Some(&body)).unwrap();
    assert_eq!(status, 502, "got {resp}");
    let doc = Json::parse(&resp).unwrap();
    assert_eq!(
        doc.get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
        Some("shard_failed")
    );

    // The health surface has settled on the terminal verdict.
    let (status, resp) = client.request("GET", "/v1/health", None).unwrap();
    assert_eq!(status, 200);
    let doc = Json::parse(&resp).unwrap();
    assert_eq!(doc.get("status").and_then(Json::as_str), Some("unhealthy"));
    assert_eq!(doc.get("healthy_workers").and_then(Json::as_f64), Some(0.0));
    let shards = doc.get("shards").and_then(Json::as_arr).unwrap();
    assert_eq!(shards.len(), 1);
    assert_eq!(shards[0].as_str(), Some("dead"));

    // Every subsequent infer is refused at the service level: one 503
    // with the Retry-After header, before any submission happens.
    let (status, head, resp) = client
        .request_with_head("POST", "/v1/infer", Some(&body))
        .unwrap();
    assert_eq!(status, 503, "got {resp}");
    let doc = Json::parse(&resp).unwrap();
    let err = doc.get("error").unwrap();
    assert_eq!(err.get("kind").and_then(Json::as_str), Some("unhealthy"));
    assert!(
        err.get("retry_after_ms").and_then(Json::as_f64).unwrap() > 0.0,
        "body must carry the millisecond hint"
    );
    assert!(
        head.lines()
            .any(|l| l.to_ascii_lowercase().starts_with("retry-after:")),
        "503 must carry a Retry-After header; head was:\n{head}"
    );

    edge.shutdown();
    drop(coord); // Drop shuts the pool down
}

/// A `SimEngine` that takes its time: every entry-point execution sleeps
/// first, so a small queue actually backs up at test scale.
struct SlowEngine {
    inner: SimEngine,
    delay: Duration,
}

impl InferenceEngine for SlowEngine {
    fn manifest(&self) -> &Manifest {
        self.inner.manifest()
    }

    fn run(&mut self, entry: &str, inputs: &[(&[f32], &Vec<usize>)]) -> bnn_cim::Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        self.inner.run(entry, inputs)
    }

    fn executions(&self) -> u64 {
        self.inner.executions()
    }

    fn name(&self) -> &'static str {
        "slow-sim"
    }
}

#[test]
fn overload_sheds_degrades_escalates_with_bounded_p99() {
    let mut cfg = Config::default();
    cfg.server.backend = Backend::Sim;
    cfg.server.workers = 1;
    cfg.server.mc_workers = 1;
    cfg.server.max_batch = 1;
    cfg.server.queue_capacity = 4;
    cfg.server.request_timeout_ms = 30_000.0;
    cfg.model.mc_samples = 4;
    // Every verdict defers (entropy is strictly positive), so every
    // degraded pass wants escalation.
    cfg.model.defer_threshold = 0.0;
    // Degrade band starts at load 0 => every expensive request takes the
    // cheap pass first; shed band at 0.5 of a 4-deep queue.
    cfg.server.edge_degrade_load = 0.0;
    cfg.server.edge_shed_load = 0.5;
    cfg.server.edge_degraded_mc_samples = 1;
    cfg.server.edge_threads = 8;

    let factory_cfg = cfg.clone();
    let coord = Arc::new(
        Coordinator::builder(cfg.clone())
            .engine_factory(Arc::new(move |_shard| {
                Ok(Box::new(SlowEngine {
                    inner: SimEngine::from_config(&factory_cfg),
                    delay: Duration::from_millis(10),
                }) as Box<dyn InferenceEngine>)
            }))
            .start()
            .unwrap(),
    );
    let edge = EdgeServer::bind("127.0.0.1:0", Arc::clone(&coord)).unwrap();
    let addr = edge.local_addr();

    let person = SyntheticPerson::new(cfg.model.image_side, 11).sample(0);
    let body = Arc::new(format!(
        "{{\"pixels\":{},\"mc_samples\":4}}",
        pixels_json(&person.pixels)
    ));

    // Phase A: a burst far beyond the queue. Every outcome must be a
    // clean 200 or a shed 429 — no dropped connections, no panics.
    let mut ok = 0u64;
    let mut shed = 0u64;
    let mut latencies: Vec<f64> = Vec::new();
    let handles: Vec<_> = (0..16)
        .map(|_| {
            let body = Arc::clone(&body);
            std::thread::spawn(move || {
                let mut out = Vec::new();
                let mut client = MiniClient::connect(addr, CLIENT_TIMEOUT).unwrap();
                for _ in 0..2 {
                    let t0 = Instant::now();
                    let (status, _) = client.request("POST", "/v1/infer", Some(&body)).unwrap();
                    out.push((status, t0.elapsed().as_secs_f64() * 1e3));
                }
                out
            })
        })
        .collect();
    for h in handles {
        for (status, ms) in h.join().unwrap() {
            match status {
                200 => {
                    ok += 1;
                    latencies.push(ms);
                }
                429 => shed += 1,
                other => panic!("unexpected status {other} under overload"),
            }
        }
    }
    assert!(ok > 0, "overload must still complete some requests");
    assert!(shed > 0, "a 4-deep queue under a 32-request burst must shed");
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p99 = latencies[(latencies.len() - 1) * 99 / 100];
    // Completed requests are bounded by the per-submission deadline
    // (cheap pass + best-effort escalation = at most two waits).
    assert!(
        p99 <= 2.5 * cfg.server.request_timeout_ms,
        "p99 {p99} ms unbounded under overload"
    );

    // Phase B: quiet again (all clients joined => nothing in flight).
    // With the degrade band at 0 and plenty of shed headroom, one probe
    // deterministically walks degrade -> deferred cheap verdict ->
    // escalate back to its full 4-sample fidelity.
    let mut client = MiniClient::connect(addr, CLIENT_TIMEOUT).unwrap();
    let (status, resp) = client.request("POST", "/v1/infer", Some(&body)).unwrap();
    assert_eq!(status, 200, "got {resp}");
    assert!(resp.contains("\"degraded\":true"), "probe not degraded: {resp}");
    assert!(resp.contains("\"escalated\":true"), "probe not escalated: {resp}");
    let doc = Json::parse(&resp).unwrap();
    assert_eq!(
        doc.get("mc_samples").and_then(Json::as_f64),
        Some(4.0),
        "escalation must restore the original fidelity"
    );

    // The ledger saw all three dispositions, and the per-shard split
    // sums to the globals (one shard here => exact equality).
    let (status, body) = client.request("GET", "/v1/metrics", None).unwrap();
    assert_eq!(status, 200);
    let doc = Json::parse(&body).unwrap();
    let global = |k: &str| doc.get(k).and_then(Json::as_f64).unwrap();
    assert!(global("requests_shed") >= shed as f64);
    assert!(global("requests_degraded") >= 1.0);
    assert!(global("requests_escalated") >= 1.0);
    let shard = &doc.get("per_shard").and_then(Json::as_arr).unwrap()[0];
    for k in ["requests_shed", "requests_degraded", "requests_escalated"] {
        assert_eq!(
            shard.get(k).and_then(Json::as_f64),
            Some(global(k)),
            "per-shard {k} must sum to the global"
        );
    }

    edge.shutdown();
    drop(coord); // Drop shuts the pool down
}
