//! Property tests for the SoA MVM fast path (ISSUE 3):
//!
//! 1. `CimTile::mvm` (precomputed bit-plane SoA) is *bit-identical* to
//!    `CimTile::mvm_legacy` (per-word AoS walk) across random tiles,
//!    programs and inputs — ideal and non-ideal analog, Bayesian and
//!    μ-only, calibrated and raw.
//! 2. The plane cache is correctly invalidated by word writes
//!    (`write_sigma_raw`, `program`): interleaving writes with MVMs never
//!    lets a stale cache leak into a result.
//! 3. `mvm_batch` is bit-identical to the same number of sequential
//!    `mvm` calls (tile and array level), while amortizing drives, plane
//!    builds and ledger deposits.
//! 4. (ISSUE 6) The runtime-dispatched SIMD arm of the MVM is
//!    bit-identical to the forced-scalar arm — at the `lane_dot` kernel
//!    level across geometries/remainders, and end-to-end through
//!    `CimTile::mvm` — so both arms run in this suite on every host
//!    regardless of its ISA (an unsupported forced level degrades to
//!    scalar, making the comparison a no-op rather than a skip).
//!
//! The file also seeds the repo-root `BENCH_cim_mvm.json` perf artifact
//! at smoke scale (the calibrated writer is `benches/cim_mvm.rs`).

use bnn_cim::arch::{detected_level, lane_dot_at, ForcedLevelGuard, SimdLevel};
use bnn_cim::cim::{CimTile, MvmOptions};
use bnn_cim::config::ChipConfig;
use bnn_cim::util::bench::{
    black_box, is_calibrated_report, quick_ns_per_iter, repo_root_artifact, write_mvm_report,
    MvmBenchCase,
};
use bnn_cim::util::propcheck::{property, Gen};
use bnn_cim::util::rng::{Pcg64, Rng64};

/// Random small-tile chip (cheap per property case, physics unchanged).
fn random_chip(g: &mut Gen) -> ChipConfig {
    let mut chip = ChipConfig::default();
    chip.tile.rows = g.usize_in(4, 24);
    chip.tile.words_per_row = g.usize_in(2, 6);
    chip.die_seed = g.u64();
    chip
}

fn random_program(tile: &mut CimTile, seed: u64, sigma_scale: f64) {
    let mut rng = Pcg64::new(seed);
    for r in 0..tile.rows() {
        for w in 0..tile.words() {
            let mu = (rng.next_f64() * 2.0 - 1.0) * 200.0;
            let sg = rng.next_f64() * sigma_scale;
            tile.program(r, w, mu, sg);
        }
    }
}

fn random_input(rows: usize, seed: u64) -> Vec<u8> {
    let mut rng = Pcg64::new(seed ^ 0xF00D);
    (0..rows).map(|_| rng.next_below(16) as u8).collect()
}

fn assert_same(a: &bnn_cim::cim::tile::MvmResult, b: &bnn_cim::cim::tile::MvmResult, ctx: &str) {
    assert_eq!(a.mu, b.mu, "μ path diverged ({ctx})");
    assert_eq!(a.sigma, b.sigma, "σε path diverged ({ctx})");
}

#[test]
fn soa_fast_path_is_bit_identical_to_legacy() {
    property("soa == legacy (bitwise)", 24, |g| {
        let chip = random_chip(g);
        // Two tiles with identical die seeds and identical histories:
        // every RNG stream advances in lockstep, so any divergence is a
        // fast-path bug, not noise.
        let mut fast = CimTile::new(&chip);
        let mut legacy = CimTile::new(&chip);
        let program_seed = g.u64();
        let sigma_scale = g.f64_in(0.0, 15.0);
        random_program(&mut fast, program_seed, sigma_scale);
        random_program(&mut legacy, program_seed, sigma_scale);
        if g.bool() {
            // Half the cases run calibrated (ADC offset + ε₀ registers
            // populated — exercises the live-register correction path).
            bnn_cim::cim::calibrate(&mut fast, 4, 4).unwrap();
            bnn_cim::cim::calibrate(&mut legacy, 4, 4).unwrap();
        }
        for case in 0..4 {
            let opts = MvmOptions {
                bayesian: g.bool() || case == 0,
                refresh_epsilon: g.bool() || case == 1,
                ideal_analog: g.bool(),
            };
            let x = random_input(fast.rows(), g.u64());
            let a = fast.mvm(&x, opts);
            let b = legacy.mvm_legacy(&x, opts);
            assert_same(&a, &b, &format!("case {case}, opts {opts:?}"));
        }
        assert_eq!(fast.ledger.grng_samples, legacy.ledger.grng_samples);
        assert_eq!(fast.ledger.mvm_count, legacy.ledger.mvm_count);
    });
}

#[test]
fn plane_cache_invalidates_on_word_writes() {
    property("plane cache invalidation", 16, |g| {
        let chip = random_chip(g);
        let mut fast = CimTile::new(&chip);
        let mut legacy = CimTile::new(&chip);
        let seed = g.u64();
        random_program(&mut fast, seed, 10.0);
        random_program(&mut legacy, seed, 10.0);
        let opts = MvmOptions::default();
        // Interleave MVMs (which build/use the cache) with σ-word and
        // full-word writes (which must invalidate it). The legacy tile
        // reads the AoS words directly, so staleness shows up as a
        // divergence on the very next MVM.
        for round in 0..4u64 {
            let x = random_input(fast.rows(), g.u64());
            assert_same(&fast.mvm(&x, opts), &legacy.mvm_legacy(&x, opts), "pre-write");
            let r = g.usize_in(0, fast.rows() - 1);
            let w = g.usize_in(0, fast.words() - 1);
            if g.bool() {
                let code = g.usize_in(0, 15) as u8;
                fast.write_sigma_raw(r, w, code);
                legacy.write_sigma_raw(r, w, code);
            } else {
                let mu = g.f64_in(-200.0, 200.0);
                let sg = g.f64_in(0.0, 15.0);
                fast.program(r, w, mu, sg);
                legacy.program(r, w, mu, sg);
            }
            let x = random_input(fast.rows(), g.u64() ^ round);
            assert_same(&fast.mvm(&x, opts), &legacy.mvm_legacy(&x, opts), "post-write");
        }
    });
}

#[test]
fn mvm_batch_is_bit_identical_to_sequential() {
    property("mvm_batch == sequential", 12, |g| {
        let chip = random_chip(g);
        let mut batched = CimTile::new(&chip);
        let mut serial = CimTile::new(&chip);
        let seed = g.u64();
        random_program(&mut batched, seed, 12.0);
        random_program(&mut serial, seed, 12.0);
        let opts = MvmOptions {
            bayesian: g.bool(),
            refresh_epsilon: g.bool(),
            ideal_analog: g.bool(),
        };
        let t = g.usize_in(1, 6);
        let x = random_input(batched.rows(), g.u64());
        let ys = batched.mvm_batch(&x, t, opts);
        assert_eq!(ys.len(), t);
        for (s, y) in ys.iter().enumerate() {
            let r = serial.mvm(&x, opts);
            assert_same(y, &r, &format!("sample {s}/{t}"));
        }
        assert_eq!(batched.ledger.mvm_count, serial.ledger.mvm_count);
        assert_eq!(batched.ledger.grng_samples, serial.ledger.grng_samples);
    });
}

#[test]
fn pipelined_mvm_batch_is_bit_identical_to_sequential() {
    // Full-size geometries (≥ 256 cells) with t ≥ 4 engage mvm_batch's
    // double-buffered ε pipeline; this randomizes program/input/options
    // over the *concurrent* arm (the small-tile batch property above
    // stays on the serial arm by design, below the cells gate).
    property("pipelined mvm_batch == sequential", 6, |g| {
        let mut chip = ChipConfig::default();
        chip.tile.rows = g.usize_in(32, 64);
        chip.tile.words_per_row = g.usize_in(8, 10);
        chip.die_seed = g.u64();
        let mut batched = CimTile::new(&chip);
        let mut serial = CimTile::new(&chip);
        let seed = g.u64();
        let sigma_scale = g.f64_in(0.0, 15.0);
        random_program(&mut batched, seed, sigma_scale);
        random_program(&mut serial, seed, sigma_scale);
        let opts = MvmOptions {
            bayesian: true,
            refresh_epsilon: true,
            ideal_analog: g.bool(),
        };
        let t = g.usize_in(4, 8);
        let x = random_input(batched.rows(), g.u64());
        let ys = batched.mvm_batch(&x, t, opts);
        assert_eq!(ys.len(), t);
        for (s, y) in ys.iter().enumerate() {
            let r = serial.mvm(&x, opts);
            assert_same(y, &r, &format!("pipelined sample {s}/{t}"));
        }
        assert_eq!(batched.last_epsilon(), serial.last_epsilon());
        assert_eq!(batched.ledger.grng_samples, serial.ledger.grng_samples);
        assert_eq!(batched.ledger.mvm_count, serial.ledger.mvm_count);
    });
}

#[test]
fn lane_dot_vector_arm_matches_scalar_across_geometries() {
    // Kernel-level pin: the dispatched vector lane_dot must agree with the
    // scalar oracle bit-for-bit on every length class mod 8 (full AVX2/NEON
    // chunks, partial chunks, empty). On a scalar-only host both arms are
    // the oracle and the property degenerates to reflexivity.
    property("lane_dot vector arm == scalar arm (bitwise)", 48, |g| {
        let n = g.usize_in(0, 131);
        let mk = |g: &mut Gen, n: usize| -> Vec<f64> {
            (0..n)
                .map(|_| match g.usize_in(0, 7) {
                    0 => 0.0,
                    1 => g.f64_in(-1e-12, 1e-12),
                    2 => g.f64_in(-1e12, 1e12),
                    _ => g.f64_in(-200.0, 200.0),
                })
                .collect()
        };
        let a = mk(g, n);
        let b = mk(g, n);
        let scalar = lane_dot_at(SimdLevel::Scalar, &a, &b);
        let vector = lane_dot_at(detected_level(), &a, &b);
        assert_eq!(
            scalar.to_bits(),
            vector.to_bits(),
            "lane_dot diverged at n={n} ({} vs scalar)",
            detected_level()
        );
    });
}

#[test]
fn forced_scalar_and_vector_mvms_are_bit_identical() {
    // End-to-end pin across the dispatch boundary: one tile runs every
    // MVM under a forced-scalar guard, its twin under the detected vector
    // level. Same die, same streams — any divergence is a vector kernel
    // breaking the determinism contract, not noise.
    property("mvm scalar arm == vector arm (bitwise)", 12, |g| {
        let chip = random_chip(g);
        let mut scalar_tile = CimTile::new(&chip);
        let mut vector_tile = CimTile::new(&chip);
        let program_seed = g.u64();
        let sigma_scale = g.f64_in(0.0, 15.0);
        random_program(&mut scalar_tile, program_seed, sigma_scale);
        random_program(&mut vector_tile, program_seed, sigma_scale);
        for case in 0..3 {
            let opts = MvmOptions {
                bayesian: g.bool() || case == 0,
                refresh_epsilon: g.bool() || case == 1,
                ideal_analog: g.bool(),
            };
            let x = random_input(scalar_tile.rows(), g.u64());
            let a = {
                let _scalar = ForcedLevelGuard::new(SimdLevel::Scalar);
                scalar_tile.mvm(&x, opts)
            };
            let b = {
                let _vector = ForcedLevelGuard::new(detected_level());
                vector_tile.mvm(&x, opts)
            };
            assert_same(&a, &b, &format!("case {case}, opts {opts:?}"));
        }
    });
}

/// Smoke-scale seed of the repo-root `BENCH_cim_mvm.json` perf artifact:
/// single-thread MVM throughput of the pre-PR AoS baseline vs the SoA
/// fast path (fresh-ε and held-ε) and the batched fast path, on the
/// default 64×8 chip tile. The calibrated (release, longer-running)
/// writer is `benches/cim_mvm.rs`; a calibrated report is never
/// overwritten by this smoke seed.
#[test]
fn bench_cim_mvm_smoke_seed() {
    let chip = ChipConfig::default();
    let ops = chip.tile.ops_per_mvm() as f64;
    let mut tile = CimTile::new(&chip);
    random_program(&mut tile, 42, 10.0);
    let x = random_input(tile.rows(), 7);
    let fresh = MvmOptions::default();
    let held = MvmOptions {
        refresh_epsilon: false,
        ..MvmOptions::default()
    };
    let target = std::time::Duration::from_millis(120);
    let batch = 16;

    let legacy_fresh = quick_ns_per_iter(|| drop(tile.mvm_legacy(&x, fresh)), 8, target);
    let soa_fresh = quick_ns_per_iter(|| drop(tile.mvm(&x, fresh)), 8, target);
    let legacy_held = quick_ns_per_iter(|| drop(tile.mvm_legacy(&x, held)), 8, target);
    let soa_held = quick_ns_per_iter(|| drop(tile.mvm(&x, held)), 8, target);
    let batch_held =
        quick_ns_per_iter(|| drop(tile.mvm_batch(&x, batch, held)), 2, target) / batch as f64;
    let batch_fresh =
        quick_ns_per_iter(|| drop(tile.mvm_batch(&x, batch, fresh)), 2, target) / batch as f64;

    // SIMD arm vs forced-scalar arm on the identical SoA path (held ε, so
    // the comparison isolates the lane_dot/mul_into kernels): end-to-end
    // MVM and the raw lane_dot kernel at the tile's row depth.
    let soa_held_scalar = {
        let _scalar = ForcedLevelGuard::new(SimdLevel::Scalar);
        quick_ns_per_iter(|| drop(tile.mvm(&x, held)), 8, target)
    };
    let soa_held_simd = {
        let _vector = ForcedLevelGuard::new(detected_level());
        quick_ns_per_iter(|| drop(tile.mvm(&x, held)), 8, target)
    };
    let mut kernel_rng = Pcg64::new(0x5EED_D07);
    let ka: Vec<f64> = (0..chip.tile.rows).map(|_| kernel_rng.next_f64() - 0.5).collect();
    let kb: Vec<f64> = (0..chip.tile.rows).map(|_| kernel_rng.next_f64() - 0.5).collect();
    let kernel_target = std::time::Duration::from_millis(40);
    let lane_dot_scalar_ns = quick_ns_per_iter(
        || {
            black_box(lane_dot_at(SimdLevel::Scalar, black_box(&ka), black_box(&kb)));
        },
        10_000,
        kernel_target,
    );
    let lane_dot_simd_ns = quick_ns_per_iter(
        || {
            black_box(lane_dot_at(detected_level(), black_box(&ka), black_box(&kb)));
        },
        10_000,
        kernel_target,
    );

    let cases = [
        MvmBenchCase::new("legacy_aos_fresh_eps", legacy_fresh, ops),
        MvmBenchCase::new("soa_fresh_eps", soa_fresh, ops),
        MvmBenchCase::new("soa_batch16_fresh_eps", batch_fresh, ops),
        MvmBenchCase::new("legacy_aos_held_eps", legacy_held, ops),
        MvmBenchCase::new("soa_held_eps", soa_held, ops),
        MvmBenchCase::new("soa_batch16_held_eps", batch_held, ops),
        MvmBenchCase::new("soa_held_eps_forced_scalar", soa_held_scalar, ops),
        MvmBenchCase::new("soa_held_eps_simd", soa_held_simd, ops),
    ];
    // Headline: MVM compute throughput (held ε — both arms would pay the
    // identical in-word sampling cost, so it cancels), batched SoA vs the
    // pre-PR per-call AoS path. Fresh-ε speedup reported alongside.
    let speedup_single_thread = legacy_held / batch_held.max(1e-9);
    let speedup_fresh = legacy_fresh / batch_fresh.max(1e-9);
    let speedup_simd_vs_scalar = soa_held_scalar / soa_held_simd.max(1e-9);
    let speedup_lane_dot = lane_dot_scalar_ns / lane_dot_simd_ns.max(1e-9);
    println!(
        "cim mvm smoke: held-ε speedup {speedup_single_thread:.2}x, \
         fresh-ε speedup {speedup_fresh:.2}x, \
         simd({}) vs scalar {speedup_simd_vs_scalar:.2}x \
         (lane_dot kernel {speedup_lane_dot:.2}x)",
        detected_level()
    );

    let root = repo_root_artifact("BENCH_cim_mvm.json");
    if is_calibrated_report(&root) {
        println!("  keeping calibrated {}", root.display());
        return;
    }
    write_mvm_report(
        &root,
        "tests/mvm_props.rs bench_cim_mvm_smoke_seed (smoke-scale, test profile)",
        chip.tile.rows,
        chip.tile.words_per_row,
        &cases,
        &[
            ("speedup_single_thread", speedup_single_thread),
            ("speedup_fresh_eps", speedup_fresh),
            ("speedup_simd_vs_scalar", speedup_simd_vs_scalar),
            ("speedup_lane_dot_simd_vs_scalar", speedup_lane_dot),
        ],
    );
}
