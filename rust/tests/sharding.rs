//! Sharded-coordinator invariants (ISSUE 1 acceptance):
//!
//! 1. Per-shard ε independence: for a fixed `die_seed` and
//!    `workers ∈ {1, 2, 4}`, shard streams are pairwise distinct.
//! 2. Single-shard bit-compatibility: with `workers = 1` the pool
//!    reproduces the pre-refactor single-worker coordinator bit for bit
//!    (same ε stream, same batch assembly, same packed head calls).
//! 3. Fixed `(die_seed, workers)` reproducibility for serial workloads
//!    (routing is round-robin on the batch id, not racy work-stealing).
//!
//! Everything runs on the deterministic `SimEngine`, so these execute in
//! every build — no artifacts, no PJRT toolchain.

use bnn_cim::bayes::aggregate_mc;
use bnn_cim::client::{Backend, Config, Coordinator, EngineFactory, Infer};
use bnn_cim::coordinator::{shard_die_seed, EpsilonSource, GrngBankSource};
use bnn_cim::data::SyntheticPerson;
use bnn_cim::runtime::{InferenceEngine, SimEngine};
use std::sync::Arc;

fn sim_engine_factory(cfg: &Config) -> EngineFactory {
    let cfg = cfg.clone();
    Arc::new(move |_shard| Ok(Box::new(SimEngine::from_config(&cfg)) as Box<dyn InferenceEngine>))
}

#[test]
fn shard_epsilon_streams_are_pairwise_distinct() {
    let cfg = Config::default();
    for &workers in &[1usize, 2, 4] {
        let mut streams = Vec::new();
        for shard in 0..workers {
            let mut src = GrngBankSource::for_shard(&cfg.chip, shard);
            let mut buf = vec![0.0f32; 256];
            src.fill(&mut buf);
            streams.push(buf);
        }
        for i in 0..workers {
            for j in (i + 1)..workers {
                assert_ne!(
                    streams[i], streams[j],
                    "workers={workers}: shards {i}/{j} drew correlated ε"
                );
            }
        }
        // Shard 0 is always the unsharded die, independent of pool size.
        let mut base = GrngBankSource::new(&cfg.chip);
        let mut buf = vec![0.0f32; 256];
        base.fill(&mut buf);
        assert_eq!(buf, streams[0]);
    }
}

#[test]
fn shard_seed_derivation_is_stable() {
    assert_eq!(shard_die_seed(0, 0), 0);
    assert_eq!(shard_die_seed(7, 0), 7);
    let a: Vec<u64> = (0..6).map(|s| shard_die_seed(7, s)).collect();
    let b: Vec<u64> = (0..6).map(|s| shard_die_seed(7, s)).collect();
    assert_eq!(a, b);
    for i in 0..a.len() {
        for j in (i + 1)..a.len() {
            assert_ne!(a[i], a[j]);
        }
    }
    // Different die seeds give different shard families.
    assert_ne!(shard_die_seed(7, 3), shard_die_seed(8, 3));
}

/// Replays the pre-refactor single-worker loop by hand — one request per
/// batch, features once, packed MC head calls with fresh ε per call — and
/// demands the `workers = 1` pool produce the exact same bits.
#[test]
fn single_shard_is_bit_identical_to_unsharded_reference() {
    let mut cfg = Config::default();
    cfg.model.mc_samples = 6;
    cfg.server.workers = 1;
    let n: u64 = 5;
    let gen = SyntheticPerson::new(cfg.model.image_side, 1234);

    // --- reference: the seed coordinator's exact op sequence ---
    let mut engine = SimEngine::from_config(&cfg);
    let mut source = GrngBankSource::new(&cfg.chip);
    let manifest = engine.manifest().clone();
    let art_batch = manifest.batch;
    let ppi = manifest.side * manifest.side;
    let classes = manifest.classes;
    let fspec = manifest.entry("features").unwrap().clone();
    let hspec = manifest.entry("head").unwrap().clone();
    let t = cfg.model.mc_samples;
    let mut expected: Vec<Vec<f64>> = Vec::new();
    for i in 0..n {
        let s = gen.sample(i);
        let mut images = vec![0.0f32; art_batch * ppi];
        images[..ppi].copy_from_slice(&s.pixels);
        let feats = engine
            .run("features", &[(&images, &fspec.inputs[0].1)])
            .unwrap();
        let feat_dim = feats.len() / art_batch;
        let mut eps1 = vec![0.0f32; hspec.input_len(1)];
        let mut eps2 = vec![0.0f32; hspec.input_len(2)];
        let mut packed = vec![0.0f32; feats.len()];
        let mut samples: Vec<Vec<f64>> = Vec::new();
        let calls = t.div_ceil(art_batch);
        for call in 0..calls {
            let mut occupied = 0usize;
            for slot in 0..art_batch {
                if call * art_batch + slot < t {
                    occupied += 1;
                    packed[slot * feat_dim..(slot + 1) * feat_dim]
                        .copy_from_slice(&feats[..feat_dim]);
                }
            }
            source.fill(&mut eps1);
            source.fill(&mut eps2);
            let probs = engine
                .run(
                    "head",
                    &[
                        (&packed, &hspec.inputs[0].1),
                        (&eps1, &hspec.inputs[1].1),
                        (&eps2, &hspec.inputs[2].1),
                    ],
                )
                .unwrap();
            for slot in 0..occupied {
                samples.push(
                    probs[slot * classes..(slot + 1) * classes]
                        .iter()
                        .map(|&v| v as f64)
                        .collect(),
                );
            }
        }
        expected.push(aggregate_mc(&samples).probs);
    }

    // --- the pool, workers = 1, serial submits (one request per batch);
    // custom engine factory + the default GRNG-bank ε sources, through
    // the v1 builder ---
    let coord = Coordinator::builder(cfg.clone())
        .engine_factory(sim_engine_factory(&cfg))
        .source_factory(GrngBankSource::shard_factory(&cfg.chip))
        .start()
        .unwrap();
    for i in 0..n {
        let s = gen.sample(i);
        let resp = coord.infer(Infer::new(s.pixels)).unwrap();
        assert_eq!(
            resp.pred.probs, expected[i as usize],
            "request {i} diverged from the unsharded reference"
        );
    }
    coord.shutdown();
}

/// Serial workloads replay identically for a fixed (die_seed, workers)
/// pair, including with a multi-worker pool: batch→shard routing is
/// deterministic round-robin and every shard's ε stream is seeded from
/// the die seed alone.
#[test]
fn fixed_seed_and_worker_count_reproduce_bitwise() {
    let run = || {
        let mut cfg = Config::default();
        cfg.model.mc_samples = 4;
        let coord = Coordinator::builder(cfg.clone())
            .backend(Backend::Sim)
            .workers(2)
            .start()
            .unwrap();
        let gen = SyntheticPerson::new(cfg.model.image_side, 9);
        let mut out = Vec::new();
        for i in 0..6 {
            out.push(coord.infer(Infer::new(gen.sample(i).pixels)).unwrap().pred.probs);
        }
        coord.shutdown();
        out
    };
    assert_eq!(run(), run(), "fixed (die_seed, workers) must replay bitwise");
}
