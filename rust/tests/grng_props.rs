//! Property tests for the SoA block-sampled GRNG bank (ISSUE 4):
//!
//! 1. `GrngBank::fill_epsilon` (contiguous block sampler over the SoA
//!    lanes) is *bit-identical* to `GrngBank::fill_epsilon_legacy` (the
//!    retained per-cell AoS walk) across random die geometries, die
//!    seeds, mismatch configs, hot dies with `p_outlier > 0`, and after
//!    `reseed_cells` — each cell's draw sequence is unchanged.
//! 2. `GrngBank::fill_epsilon_planes` (the plane-major `[word][row]`
//!    variant the CIM tile consumes directly) is the exact transpose of
//!    the row-major conversion, cell for cell, bit for bit.
//! 3. `shard_die_seed` (now an O(1) SplitMix64 jump) matches the pre-PR
//!    O(shard) split loop bit-for-bit.
//! 4. (ISSUE 6) The vectorized Gaussian block pass (SIMD xoshiro sweep +
//!    per-lane ziggurat finish + dispatched normalize) is bit-identical
//!    to the forced-scalar arm, replays deterministically, and passes
//!    distributional gates (moments, normal QQ correlation, lag-1
//!    autocorrelation) — both arms run on every host, since an
//!    unsupported forced level degrades to scalar.
//!
//! The file also seeds the repo-root `BENCH_grng_fill.json` perf artifact
//! at smoke scale (the calibrated writer is `benches/grng.rs`).

use bnn_cim::arch::{detected_level, ForcedLevelGuard, SimdLevel};
use bnn_cim::config::ChipConfig;
use bnn_cim::grng::{shard_die_seed, GrngBank};
use bnn_cim::util::bench::{
    is_calibrated_report, quick_ns_per_iter, repo_root_artifact, write_grng_fill_report,
    GrngFillCase,
};
use bnn_cim::util::propcheck::{property, Gen};
use bnn_cim::util::rng::SplitMix64;
use bnn_cim::util::stats::{pearson, qq_r_value, Summary};

/// Random small-bank chip (cheap per property case, physics unchanged).
/// Half the cases run a hot die (60 °C), where the outlier probability is
/// no longer negligible, so the sparse outlier pass genuinely fires.
fn random_chip(g: &mut Gen) -> ChipConfig {
    let mut chip = ChipConfig::default();
    chip.tile.rows = g.usize_in(2, 24);
    chip.tile.words_per_row = g.usize_in(1, 6);
    chip.die_seed = g.u64();
    if g.bool() {
        chip.grng.temp_c = 60.0;
    }
    if g.bool() {
        chip.grng.mismatch_rel_sigma = g.f64_in(0.0, 0.05);
    }
    chip
}

#[test]
fn block_fill_is_bit_identical_to_legacy() {
    property("fill_epsilon == fill_epsilon_legacy (bitwise)", 24, |g| {
        let chip = random_chip(g);
        let mut block = GrngBank::for_chip(&chip);
        let mut legacy = GrngBank::for_chip(&chip);
        let n = block.len();
        let mut a = vec![0.0; n];
        let mut b = vec![0.0; n];
        for round in 0..3 {
            block.fill_epsilon(&mut a);
            legacy.fill_epsilon_legacy(&mut b);
            assert_eq!(a, b, "round {round} (chip {:?})", chip.grng.temp_c);
        }
        // Reseeded streams stay pinned too.
        let seed = g.u64();
        block.reseed_cells(seed);
        legacy.reseed_cells(seed);
        for round in 0..2 {
            block.fill_epsilon(&mut a);
            legacy.fill_epsilon_legacy(&mut b);
            assert_eq!(a, b, "post-reseed round {round}");
        }
        assert_eq!(block.samples_drawn(), legacy.samples_drawn());
    });
}

#[test]
fn plane_major_fill_is_the_exact_transpose() {
    property("fill_epsilon_planes == transpose(fill_epsilon)", 20, |g| {
        let chip = random_chip(g);
        let mut row_major = GrngBank::for_chip(&chip);
        let mut planes = GrngBank::for_chip(&chip);
        let rows = chip.tile.rows;
        let words = chip.tile.words_per_row;
        let n = rows * words;
        let mut a = vec![0.0; n];
        let mut b = vec![0.0; n];
        for round in 0..3 {
            row_major.fill_epsilon(&mut a);
            planes.fill_epsilon_planes(&mut b);
            for r in 0..rows {
                for w in 0..words {
                    assert_eq!(
                        a[r * words + w].to_bits(),
                        b[w * rows + r].to_bits(),
                        "cell ({r},{w}) round {round}"
                    );
                }
            }
        }
    });
}

#[test]
fn hot_die_block_path_produces_outlier_tails() {
    // At 60 °C the outlier probability is ≈1.5 %, so a few hundred
    // whole-bank conversions must show heavy tails — proof the sparse
    // second pass of the block sampler actually executes in this suite.
    let mut chip = ChipConfig::default();
    chip.grng.temp_c = 60.0;
    let mut bank = GrngBank::for_chip(&chip);
    let n = bank.len();
    let mut buf = vec![0.0; n];
    let mut extremes = 0usize;
    for _ in 0..20 {
        bank.fill_epsilon(&mut buf);
        extremes += buf.iter().filter(|v| v.abs() > 5.0).count();
    }
    assert!(extremes > 0, "60 °C bank must produce outlier tails");
}

#[test]
fn forced_scalar_and_vector_fills_are_bit_identical() {
    // Dispatch-boundary pin: twin banks (same die, same streams) run the
    // block fill under forced-scalar vs the detected vector level. The
    // vector arm's SIMD xoshiro sweep and dispatched normalize must not
    // shift a single bit, in either output layout.
    property("fill scalar arm == vector arm (bitwise)", 12, |g| {
        let chip = random_chip(g);
        let mut scalar_bank = GrngBank::for_chip(&chip);
        let mut vector_bank = GrngBank::for_chip(&chip);
        let n = scalar_bank.len();
        let mut a = vec![0.0; n];
        let mut b = vec![0.0; n];
        for round in 0..3 {
            let planes = round % 2 == 1;
            {
                let _scalar = ForcedLevelGuard::new(SimdLevel::Scalar);
                if planes {
                    scalar_bank.fill_epsilon_planes(&mut a);
                } else {
                    scalar_bank.fill_epsilon(&mut a);
                }
            }
            {
                let _vector = ForcedLevelGuard::new(detected_level());
                if planes {
                    vector_bank.fill_epsilon_planes(&mut b);
                } else {
                    vector_bank.fill_epsilon(&mut b);
                }
            }
            assert_eq!(a, b, "round {round} (planes={planes})");
        }
    });
}

#[test]
fn vectorized_fill_replays_deterministically() {
    // Replay gate: two identically-seeded banks under the dispatched
    // (vector where available) arm must produce the same ε stream fill
    // after fill; a reseed re-pins the stream.
    let chip = ChipConfig::default();
    let _vector = ForcedLevelGuard::new(detected_level());
    let mut a_bank = GrngBank::for_chip(&chip);
    let mut b_bank = GrngBank::for_chip(&chip);
    let n = a_bank.len();
    let mut a = vec![0.0; n];
    let mut b = vec![0.0; n];
    for round in 0..5 {
        a_bank.fill_epsilon_planes(&mut a);
        b_bank.fill_epsilon_planes(&mut b);
        assert_eq!(a, b, "replay diverged at round {round}");
    }
    a_bank.reseed_cells(0xCAFE);
    b_bank.reseed_cells(0xCAFE);
    a_bank.fill_epsilon(&mut a);
    b_bank.fill_epsilon(&mut b);
    assert_eq!(a, b, "replay diverged after reseed");
}

#[test]
fn vectorized_fill_passes_correlation_gates() {
    // Distributional gate on the vectorized arm: ε over many conversions
    // of the default cold 64×8 die must look standard-normal — moments,
    // normal QQ correlation, and no lag-1 autocorrelation (a vertical
    // SIMD sweep that cross-wired adjacent lanes' states would light this
    // up immediately).
    let chip = ChipConfig::default();
    let _vector = ForcedLevelGuard::new(detected_level());
    let mut bank = GrngBank::for_chip(&chip);
    let n = bank.len();
    let mut buf = vec![0.0; n];
    let mut stream = Vec::with_capacity(n * 200);
    for _ in 0..200 {
        bank.fill_epsilon(&mut buf);
        stream.extend_from_slice(&buf);
    }
    let s = Summary::from_slice(&stream);
    // The mean carries the die's fixed per-cell offsets (they do not
    // average out with more fills), so the gate is on the die scale.
    assert!(s.mean().abs() < 0.1, "ε mean {} drifted", s.mean());
    assert!(
        (0.8..1.3).contains(&s.std()),
        "ε std {} out of range",
        s.std()
    );
    // Same threshold as the chip-sample gate in `grng::quality`.
    let qq = qq_r_value(&stream);
    assert!(qq > 0.985, "normal QQ correlation {qq} too low");
    let lag1 = pearson(&stream[..stream.len() - 1], &stream[1..]);
    assert!(
        lag1.abs() < 0.05,
        "lag-1 autocorrelation {lag1} — lanes are cross-correlated"
    );
}

#[test]
fn shard_die_seed_jump_matches_the_split_loop() {
    // Reference: the pre-PR O(shard) implementation, looping the
    // splitter `shard` times.
    fn reference(die_seed: u64, shard: usize) -> u64 {
        if shard == 0 {
            return die_seed;
        }
        let mut splitter = SplitMix64::new(die_seed ^ 0xD1E5_EED5_0F5A_A5F1);
        let mut seed = die_seed;
        for _ in 0..shard {
            seed = splitter.split();
        }
        seed
    }
    for &seed in &[0u64, 1, 42, 0xC0FFEE, u64::MAX] {
        for shard in 0..64 {
            assert_eq!(
                shard_die_seed(seed, shard),
                reference(seed, shard),
                "seed {seed} shard {shard}"
            );
        }
    }
}

/// Smoke-scale seed of the repo-root `BENCH_grng_fill.json` perf
/// artifact: whole-bank fill throughput of the SoA block sampler
/// (row-major and plane-major) vs the retained AoS walk, on the default
/// 64×8 chip bank. The calibrated (release, longer-running) writer is
/// `benches/grng.rs`; a calibrated report is never overwritten by this
/// smoke seed.
#[test]
fn bench_grng_fill_smoke_seed() {
    let chip = ChipConfig::default();
    let cells = chip.tile.rows * chip.tile.words_per_row;
    let mut buf = vec![0.0f64; cells];
    let target = std::time::Duration::from_millis(100);

    let mut bank_block = GrngBank::for_chip(&chip);
    let block = quick_ns_per_iter(|| bank_block.fill_epsilon(&mut buf), 16, target);
    let mut bank_planes = GrngBank::for_chip(&chip);
    let planes = quick_ns_per_iter(|| bank_planes.fill_epsilon_planes(&mut buf), 16, target);
    let mut bank_legacy = GrngBank::for_chip(&chip);
    let legacy = quick_ns_per_iter(|| bank_legacy.fill_epsilon_legacy(&mut buf), 16, target);
    // SIMD arm vs forced-scalar arm of the identical block fill.
    let mut bank_scalar = GrngBank::for_chip(&chip);
    let block_scalar = {
        let _scalar = ForcedLevelGuard::new(SimdLevel::Scalar);
        quick_ns_per_iter(|| bank_scalar.fill_epsilon_planes(&mut buf), 16, target)
    };
    let mut bank_simd = GrngBank::for_chip(&chip);
    let block_simd = {
        let _vector = ForcedLevelGuard::new(detected_level());
        quick_ns_per_iter(|| bank_simd.fill_epsilon_planes(&mut buf), 16, target)
    };

    let gsa_per_s = cells as f64 / block.max(1e-9);
    let speedup_block_vs_legacy = legacy / block.max(1e-9);
    let speedup_planes_vs_legacy = legacy / planes.max(1e-9);
    let speedup_simd_vs_scalar = block_scalar / block_simd.max(1e-9);
    println!(
        "grng fill smoke: block {block:.0} ns/fill, planes {planes:.0} ns/fill, \
         legacy {legacy:.0} ns/fill, speedup {speedup_block_vs_legacy:.2}x, \
         simd({}) vs scalar {speedup_simd_vs_scalar:.2}x, {gsa_per_s:.4} GSa/s",
        detected_level()
    );

    let root = repo_root_artifact("BENCH_grng_fill.json");
    if is_calibrated_report(&root) {
        println!("  keeping calibrated {}", root.display());
        return;
    }
    write_grng_fill_report(
        &root,
        "tests/grng_props.rs bench_grng_fill_smoke_seed (smoke-scale, test profile)",
        chip.tile.rows,
        chip.tile.words_per_row,
        &[
            GrngFillCase::new("block_soa", block, cells),
            GrngFillCase::new("block_soa_planes", planes, cells),
            GrngFillCase::new("legacy_aos", legacy, cells),
            GrngFillCase::new("block_soa_planes_forced_scalar", block_scalar, cells),
            GrngFillCase::new("block_soa_planes_simd", block_simd, cells),
        ],
        &[
            ("gsa_per_s", gsa_per_s),
            ("speedup_block_vs_legacy", speedup_block_vs_legacy),
            ("speedup_planes_vs_legacy", speedup_planes_vs_legacy),
            ("speedup_simd_vs_scalar", speedup_simd_vs_scalar),
        ],
    );
}
