//! Integration tests across layer boundaries.
//!
//! Most of these need `make artifacts` (they exercise the real AOT
//! pipeline); they skip gracefully when artifacts are absent so
//! `cargo test` stays green on a fresh checkout.

use bnn_cim::client::{Backend, Config, Coordinator, Infer};
use bnn_cim::data::SyntheticPerson;
use bnn_cim::nn::Model;
use bnn_cim::util::stats::pearson;
use std::path::Path;

fn artifacts_ready() -> bool {
    Path::new("artifacts/manifest.json").exists()
}

/// The PJRT-executed feature extractor (JAX-lowered) and the rust-native
/// re-implementation must agree on the SAME trained weights — this pins
/// the L2↔L3 semantic contract (conv layout, padding, ReLU6, GAP).
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_features_match_rust_native_layers() {
    use bnn_cim::runtime::Engine;
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut engine = Engine::load(Path::new("artifacts")).unwrap();
    let manifest = engine.manifest().clone();
    let model = Model::load(&manifest.weights_path()).unwrap();
    let spec = manifest.entry("features").unwrap().clone();
    let b = manifest.batch;
    let ppi = manifest.side * manifest.side;

    let gen = SyntheticPerson::new(manifest.side, 99);
    let mut images = vec![0.0f32; b * ppi];
    let mut native = Vec::new();
    for i in 0..b {
        let s = gen.sample(i as u64);
        images[i * ppi..(i + 1) * ppi].copy_from_slice(&s.pixels);
        native.extend(model.forward_features(&s.pixels));
    }
    let pjrt = engine
        .run("features", &[(&images, &spec.inputs[0].1)])
        .unwrap();
    assert_eq!(pjrt.len(), native.len());
    let mut max_err = 0.0f32;
    for (a, b) in pjrt.iter().zip(native.iter()) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(
        max_err < 1e-3,
        "PJRT vs rust-native feature mismatch: max err {max_err}"
    );
}

/// Predictions through the coordinator with a deterministic ε source are
/// reproducible end to end (batching, padding, MC loop included).
/// Needs the PJRT engine behind the custom ε source factory.
#[cfg(feature = "pjrt")]
#[test]
fn coordinator_deterministic_with_philox_source() {
    use bnn_cim::coordinator::PhiloxSource;
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let run = || {
        let mut cfg = Config::default();
        cfg.model.mc_samples = 6;
        let coord = Coordinator::builder(cfg)
            .backend(Backend::Pjrt)
            .source_factory(PhiloxSource::shard_factory(7))
            .start()
            .unwrap();
        let gen = SyntheticPerson::new(32, 3);
        let mut probs = Vec::new();
        for i in 0..6 {
            let r = coord.infer(Infer::new(gen.sample(i).pixels)).unwrap();
            probs.push(r.pred.probs.clone());
        }
        coord.shutdown();
        probs
    };
    // NOTE: identical results require identical batching; serial
    // blocking `infer` guarantees one request per batch on both runs.
    let a = run();
    let b = run();
    for (x, y) in a.iter().zip(b.iter()) {
        for (p, q) in x.iter().zip(y.iter()) {
            assert!((p - q).abs() < 1e-9, "non-deterministic: {p} vs {q}");
        }
    }
}

/// The exported eval batch (written by python training) must classify
/// consistently between the PJRT path and the training-side accuracy.
#[test]
fn eval_batch_accuracy_matches_training_metrics() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let doc = bnn_cim::util::json::Json::read_file(Path::new("artifacts/eval_batch.json"))
        .unwrap();
    let imgs = doc.get("id_images").unwrap().as_arr().unwrap();
    let labels = doc.get("id_labels").unwrap().as_usize_vec().unwrap();
    let metrics =
        bnn_cim::util::json::Json::read_file(Path::new("artifacts/train_metrics.json")).unwrap();
    let trained_acc = metrics.get("det_val_acc").unwrap().as_f64().unwrap();

    let model = Model::load(Path::new("artifacts/weights.json")).unwrap();
    let n = 128.min(imgs.len());
    let mut correct = 0;
    for i in 0..n {
        let px = imgs[i].as_f32_vec().unwrap();
        let feats = model.forward_features(&px);
        let p = model.predict_det(&feats);
        if (p[1] > p[0]) as usize == labels[i] {
            correct += 1;
        }
    }
    let acc = correct as f64 / n as f64;
    assert!(
        (acc - trained_acc).abs() < 0.12,
        "rust-native eval acc {acc:.3} vs training-side {trained_acc:.3}"
    );
}

/// Hardware-sim arm and float arm must produce correlated mean
/// predictions on the trained model (the chip computes the same model).
#[test]
fn hw_and_float_arms_agree_on_trained_model() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut model = Model::load(Path::new("artifacts/weights.json")).unwrap();
    model.map_head_to_hardware(&bnn_cim::config::ChipConfig::default());
    let gen = SyntheticPerson::new(32, 17);
    let mut hw_p1 = Vec::new();
    let mut fl_p1 = Vec::new();
    for i in 0..24 {
        let s = gen.sample(i);
        let hw = model.predict_bayes(&s.pixels, 8, true);
        let fl = model.predict_bayes(&s.pixels, 8, false);
        hw_p1.push(hw.probs[1]);
        fl_p1.push(fl.probs[1]);
    }
    let r = pearson(&hw_p1, &fl_p1);
    assert!(r > 0.8, "hw vs float prediction correlation {r}");
}

/// Backpressure: a tiny queue rejects the overflow instead of deadlocking.
/// Runs on the sim engine, so this exercises the real dispatcher/worker
/// pool in every build — no artifacts required.
#[test]
fn coordinator_backpressure_rejects_cleanly() {
    let mut cfg = Config::default();
    cfg.server.queue_capacity = 2;
    cfg.model.mc_samples = 2;
    cfg.server.batch_deadline_ms = 50.0;
    let coord = Coordinator::builder(cfg)
        .backend(Backend::Sim)
        .start()
        .unwrap();
    let gen = SyntheticPerson::new(32, 23);
    let mut accepted = Vec::new();
    let mut rejected = 0;
    for i in 0..64 {
        match coord.submit(Infer::new(gen.sample(i).pixels)) {
            Ok(ticket) => accepted.push(ticket),
            Err(_) => rejected += 1,
        }
    }
    // Everything accepted must complete.
    for ticket in accepted {
        ticket
            .wait_timeout(std::time::Duration::from_secs(60))
            .unwrap();
    }
    let m = coord.metrics();
    assert_eq!(m.requests_total + m.requests_rejected, 64);
    assert_eq!(m.requests_rejected, rejected);
    coord.shutdown();
}

/// A dropped [`bnn_cim::client::Ticket`] (or a timed-out blocking call)
/// leaves the shard worker replying into a dead channel. The worker must
/// survive, and the served-but-undeliverable response must surface as
/// `requests_orphaned` (per-shard and globally) instead of vanishing.
#[test]
fn dropped_ticket_counts_as_orphaned_not_a_crash() {
    let mut cfg = Config::default();
    cfg.model.mc_samples = 2;
    cfg.server.batch_deadline_ms = 1.0;
    let coord = Coordinator::builder(cfg)
        .backend(Backend::Sim)
        .start()
        .unwrap();
    let gen = SyntheticPerson::new(32, 5);
    // Abandon the first request before its response arrives.
    drop(coord.submit(Infer::new(gen.sample(0).pixels)).unwrap());
    // A following blocking request on the same single-shard pool proves
    // the worker survived; batches are served in order, so by the time
    // this response arrives the orphaned reply has been counted.
    let resp = coord.infer(Infer::new(gen.sample(1).pixels)).unwrap();
    assert_eq!(resp.pred.probs.len(), 2);
    let m = coord.metrics();
    assert_eq!(m.requests_orphaned, 1, "orphaned reply must be counted");
    assert_eq!(m.per_shard[0].requests_orphaned, 1);
    assert_eq!(m.requests_total, 2, "the orphaned request was still served");
    assert!(m.render().contains("orphaned=1"));
    coord.shutdown();
}
