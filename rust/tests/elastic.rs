//! Elastic capacity acceptance (ISSUE 9): copy-on-calibrate shared tile
//! state under autoscaling, work stealing, and online model swap.
//!
//! 1. A burst against a small `max_batch` drives the dispatcher's
//!    scale-up policy; every ticket resolves (zero lost), and once the
//!    burst drains the idle decay returns the replica pool to the floor.
//! 2. `Coordinator::swap_model` is zero-downtime: requests keep
//!    succeeding across a publish-drain-flip, and the swap counter
//!    proves the worker actually flipped engines.
//! 3. `set_replica_target` is the deterministic escape hatch: clamped to
//!    `[min_mc_workers, max_mc_workers]`, applied at the next batch
//!    boundary, visible through `replica_target` and the
//!    `replicas_active` gauge — and the footprint gauges split into a
//!    nonzero shared (Arc'd weights + calibration) and private
//!    (ε buffers + scratch) layer.
//!
//! Scale-up/scale-down run on the cim backend so the replica pool being
//! resized is the real Arc-sharing engine, not a no-op stub.

use bnn_cim::client::{Backend, Config, Coordinator, EngineFactory, Infer, MetricsSnapshot};
use bnn_cim::data::SyntheticPerson;
use bnn_cim::runtime::{InferenceEngine, SimEngine};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Small-tile cim config: cheap bring-up in debug builds, serial batches.
fn elastic_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.model.mc_samples = 4;
    cfg.chip.tile.rows = 16;
    cfg.chip.tile.words_per_row = 4;
    cfg.server.max_batch = 1;
    cfg.server.batch_deadline_ms = 1.0;
    cfg.server.request_timeout_ms = 30_000.0;
    cfg
}

/// Poll the metrics snapshot until `pred` holds or ~5 s elapse.
fn wait_for(coord: &Coordinator, pred: impl Fn(&MetricsSnapshot) -> bool) -> MetricsSnapshot {
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let m = coord.metrics();
        if pred(&m) || Instant::now() >= deadline {
            return m;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Burst → scale up; drain → decay back to the floor. Zero lost tickets
/// throughout: elasticity changes *throughput shape*, never delivery.
#[test]
fn burst_scales_up_and_idle_decays_to_floor_with_no_lost_tickets() {
    let coord = Coordinator::builder(elastic_cfg())
        .backend(Backend::Cim)
        .workers(1)
        .mc_workers(1)
        .elastic(true)
        .min_mc_workers(1)
        .max_mc_workers(4)
        .start()
        .unwrap();

    // Burst: with max_batch = 1, the dispatcher sees queue depth ≥ 2
    // after nearly every batch it assembles and raises the target.
    let gen = SyntheticPerson::new(32, 44);
    let tickets = coord
        .submit_many((0..16).map(|i| Infer::new(gen.sample(i).pixels)))
        .unwrap();
    for t in tickets {
        t.wait_timeout(Duration::from_secs(30))
            .expect("elastic pool must not lose tickets");
    }

    let m = coord.metrics();
    assert!(
        m.scale_up >= 1,
        "a 16-deep burst over max_batch = 1 must trigger scale-up (scale_up = {})",
        m.scale_up
    );

    // Drained: the idle decay walks the pool back to min_mc_workers and
    // refreshes the gauge from inside the worker's idle tick.
    let m = wait_for(&coord, |m| m.scale_down >= 1 && m.per_shard[0].replicas_active == 1);
    assert!(
        m.scale_down >= 1,
        "an idle elastic pool must decay (scale_down = {})",
        m.scale_down
    );
    assert_eq!(
        m.per_shard[0].replicas_active, 1,
        "idle decay must return the pool to min_mc_workers"
    );

    coord.shutdown();
}

/// Publish-drain-flip under traffic: every request around the swap
/// succeeds, and the flip is observable in `model_swaps`. Works with
/// elasticity OFF — hot swap is a batch-boundary mechanism, not an
/// autoscaler feature.
#[test]
fn model_swap_under_traffic_is_zero_downtime() {
    let mut cfg = Config::default();
    cfg.model.mc_samples = 4;
    cfg.server.max_batch = 1;
    cfg.server.batch_deadline_ms = 1.0;
    cfg.server.request_timeout_ms = 30_000.0;
    let coord = Coordinator::builder(cfg.clone())
        .backend(Backend::Sim)
        .workers(1)
        .start()
        .unwrap();

    let gen = SyntheticPerson::new(32, 45);
    for i in 0..3 {
        coord.infer(Infer::new(gen.sample(i).pixels)).unwrap();
    }

    // Publish a fresh engine build; the worker flips at its next batch
    // boundary, so the very next request is served by the new engine.
    let swap_cfg = cfg.clone();
    let factory: EngineFactory = Arc::new(move |_shard| {
        Ok(Box::new(SimEngine::from_config(&swap_cfg)) as Box<dyn InferenceEngine>)
    });
    let generation = coord.swap_model(factory);
    assert!(generation >= 2, "publish must advance the generation");

    for i in 3..6 {
        coord
            .infer(Infer::new(gen.sample(i).pixels))
            .expect("requests across a model swap must keep succeeding");
    }
    let m = wait_for(&coord, |m| m.model_swaps >= 1);
    assert!(
        m.model_swaps >= 1,
        "the worker must have flipped to the published engine (swaps = {})",
        m.model_swaps
    );

    coord.shutdown();
}

/// Manual replica targeting: clamped into the configured band, applied
/// at the next batch boundary, and reflected in both `replica_target`
/// and the `replicas_active` gauge. The footprint gauges prove the
/// copy-on-calibrate split: a nonzero Arc-shared layer and a nonzero
/// per-replica private layer.
#[test]
fn set_replica_target_is_clamped_applied_and_splits_footprint() {
    let mut cfg = elastic_cfg();
    cfg.server.mc_workers = 2;
    cfg.server.min_mc_workers = 1;
    cfg.server.max_mc_workers = 4;
    let coord = Coordinator::builder(cfg)
        .backend(Backend::Cim)
        .workers(1)
        .start()
        .unwrap();
    let gen = SyntheticPerson::new(32, 46);

    // Boot target is mc_workers.
    assert_eq!(coord.replica_target(0), 2);

    // Above the band: clamped to max_mc_workers, applied on next batch.
    coord.set_replica_target(0, 99);
    assert_eq!(coord.replica_target(0), 4);
    coord.infer(Infer::new(gen.sample(0).pixels)).unwrap();
    let m = coord.metrics();
    assert_eq!(m.per_shard[0].replicas_active, 4);

    // Below the band: clamped to min_mc_workers.
    coord.set_replica_target(0, 0);
    assert_eq!(coord.replica_target(0), 1);
    coord.infer(Infer::new(gen.sample(1).pixels)).unwrap();
    let m = coord.metrics();
    assert_eq!(m.per_shard[0].replicas_active, 1);

    // Copy-on-calibrate footprint split: weights/calibration are shared
    // behind Arc, only ε buffers and scratch are per-replica.
    assert!(m.bytes_shared > 0, "shared layer must be reported");
    assert!(m.bytes_private > 0, "private layer must be reported");
    assert!(
        m.bytes_shared > m.bytes_private,
        "shared weights/calibration ({} B) should dominate per-replica \
         private state ({} B) at 1 replica",
        m.bytes_shared,
        m.bytes_private
    );

    coord.shutdown();
}
