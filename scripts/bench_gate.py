#!/usr/bin/env python3
"""CI regression gate for the repo-root BENCH_*.json perf artifacts.

Run from the repo root after a bench suite has regenerated its reports
(tests/mvm_props.rs, tests/grng_props.rs, tests/backend_smoke.rs write
smoke-scale seeds; benches/* write calibrated reports; benches/edge_load.rs
writes the HTTP load curve):

    python3 scripts/bench_gate.py                  # gate every report
    python3 scripts/bench_gate.py BENCH_edge.json  # gate only these files

CI jobs pass the files their suite actually regenerates, so a job never
fails on a placeholder another job owns (bench-smoke gates the kernel
reports, edge-smoke gates BENCH_edge.json).

Rules:

- A report carrying `"placeholder": true` is a checked-in seed that never
  came from a measurement run. A *fresh* placeholder fails its gate (the
  suite did not regenerate it); a placeholder *baseline* merely skips the
  regression comparison so the first real numbers can land.
- Each gated file has a headline field that must be a positive number.
- The fresh headline is compared against the checked-in baseline
  (`git show HEAD:<file>`): a drop below REGRESSION_FRACTION fails.
- BENCH_cim_mvm.json only: when the fresh report ran on a vector
  `simd_level` (not "scalar"), the kernel-level
  `speedup_lane_dot_simd_vs_scalar` must be at least
  MIN_SIMD_KERNEL_SPEEDUP — the ISSUE 6 acceptance bar for the
  vectorized lane_dot on the 64-row geometry.
- BENCH_edge.json only: the `overload` point (the sweep point offered
  above measured capacity) must show the admission machine engaging —
  `shed + degraded + escalated > 0` — while `p99_bounded` stays true
  (p99 latency within the configured request timeout).
- BENCH_chaos.json only (written by tests/chaos.rs when
  BNN_CIM_CHAOS_REPORT names the output path): conservation — every
  submitted ticket resolved (`completed + failed_typed == submitted`) —
  and the kill actually happened (`shard_restarts > 0`) with recovered
  work redelivered (`requests_retried > 0`).
- BENCH_elastic.json only (written by benches/elastic.rs): the
  copy-on-calibrate split must hold — the Arc-shared immutable layer
  strictly larger than one replica's private state — and the scale
  event must show the autoscaler engaging (`scale_event.scale_up > 0`)
  while both throughput points stay positive. The headline
  `replica_boot_speedup` (full bring-up / replica grow) is tracked
  against the checked-in baseline like every other headline.

Exit code 0 = all gates pass; 1 = any gate fails (fails the CI job).
"""

import json
import subprocess
import sys

REGRESSION_FRACTION = 0.8  # fresh must be >= 80% of a real baseline
MIN_SIMD_KERNEL_SPEEDUP = 1.5

# file -> headline field that must be positive and non-regressing
GATES = {
    "BENCH_cim_mvm.json": "speedup_single_thread",
    "BENCH_grng_fill.json": "speedup_block_vs_legacy",
    "BENCH_edge.json": "peak_completed_rps",
    "BENCH_chaos.json": "completed",
    "BENCH_elastic.json": "replica_boot_speedup",
}

failures = []


def load_fresh(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        failures.append(f"{path}: unreadable ({e})")
        return None


def load_baseline(path):
    """The checked-in report at HEAD, or None if absent/unreadable."""
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD:{path}"],
            capture_output=True,
            text=True,
            check=True,
        ).stdout
        return json.loads(out)
    except (subprocess.CalledProcessError, ValueError):
        return None


def is_placeholder(doc):
    """A report that never came from a real measurement run. The explicit
    `placeholder` field is authoritative; the source-string fallback keeps
    pre-field baselines in history recognizable."""
    if doc is None:
        return True
    if doc.get("placeholder") is True:
        return True
    return "placeholder" in doc.get("source", "") and "placeholder" not in doc


def gate_headline(path, field):
    fresh = load_fresh(path)
    if fresh is None:
        return None
    if is_placeholder(fresh):
        failures.append(
            f"{path}: still a placeholder — the bench suite did not "
            f"regenerate it"
        )
        return None
    value = fresh.get(field, 0.0)
    if not isinstance(value, (int, float)) or value <= 0.0:
        failures.append(
            f"{path}: {field} = {value!r} — bench did not produce a real "
            f"number"
        )
        return fresh
    print(f"{path}: {field} = {value:.3f}")

    baseline = load_baseline(path)
    if is_placeholder(baseline):
        print(f"{path}: baseline is a placeholder — nonzero check only")
        return fresh
    base = baseline.get(field, 0.0)
    if isinstance(base, (int, float)) and base > 0.0:
        floor = base * REGRESSION_FRACTION
        if value < floor:
            failures.append(
                f"{path}: {field} regressed: {value:.3f} < {floor:.3f} "
                f"({REGRESSION_FRACTION:.0%} of baseline {base:.3f})"
            )
        else:
            print(
                f"{path}: within {REGRESSION_FRACTION:.0%} of baseline "
                f"{base:.3f}"
            )
    return fresh


def gate_simd_kernel(mvm):
    """SIMD kernel bar: only when the fresh report ran on a vector arm."""
    level = mvm.get("simd_level", "scalar")
    if level == "scalar":
        print("BENCH_cim_mvm.json: scalar host — SIMD kernel bar skipped")
        return
    kernel = mvm.get("speedup_lane_dot_simd_vs_scalar", 0.0)
    if not isinstance(kernel, (int, float)) or kernel < MIN_SIMD_KERNEL_SPEEDUP:
        failures.append(
            f"BENCH_cim_mvm.json: simd_level={level} but "
            f"speedup_lane_dot_simd_vs_scalar = {kernel!r} < "
            f"{MIN_SIMD_KERNEL_SPEEDUP} — vectorized lane_dot is not "
            f"pulling its weight"
        )
    else:
        print(
            f"BENCH_cim_mvm.json: lane_dot {level} speedup {kernel:.2f}x "
            f">= {MIN_SIMD_KERNEL_SPEEDUP}x"
        )


def gate_edge_overload(edge):
    """The admission machine must visibly engage at the overload point
    while keeping tail latency bounded."""
    overload = edge.get("overload")
    if not isinstance(overload, dict):
        failures.append(
            "BENCH_edge.json: no overload point — the sweep never offered "
            "load above measured capacity"
        )
        return
    engaged = sum(
        overload.get(k, 0) or 0 for k in ("shed", "degraded", "escalated")
    )
    if engaged <= 0:
        failures.append(
            f"BENCH_edge.json: overload point shows no admission activity "
            f"(shed={overload.get('shed')!r}, "
            f"degraded={overload.get('degraded')!r}, "
            f"escalated={overload.get('escalated')!r}) at "
            f"{overload.get('offered_rps', 0):.0f} rps offered"
        )
    else:
        print(
            f"BENCH_edge.json: overload engaged admission "
            f"(shed+degraded+escalated = {engaged:.0f})"
        )
    if overload.get("p99_bounded") is not True:
        failures.append(
            f"BENCH_edge.json: overload p99 {overload.get('p99_ms', 0):.1f} ms "
            f"exceeded the request timeout (p99_bounded = "
            f"{overload.get('p99_bounded')!r})"
        )
    else:
        print(
            f"BENCH_edge.json: overload p99 {overload.get('p99_ms', 0):.1f} ms "
            f"within the request timeout"
        )


def gate_chaos_conservation(chaos):
    """Zero lost tickets under the kill: conservation must hold exactly,
    and the chaos run must have actually exercised the supervisor."""
    submitted = chaos.get("submitted", 0) or 0
    completed = chaos.get("completed", 0) or 0
    failed_typed = chaos.get("failed_typed", 0) or 0
    if submitted <= 0:
        failures.append("BENCH_chaos.json: no submissions recorded")
        return
    if completed + failed_typed != submitted:
        failures.append(
            f"BENCH_chaos.json: ticket conservation violated — "
            f"completed {completed} + failed_typed {failed_typed} != "
            f"submitted {submitted} (lost/hung tickets)"
        )
    else:
        print(
            f"BENCH_chaos.json: conservation holds "
            f"({completed} completed + {failed_typed} typed failures "
            f"= {submitted} submitted)"
        )
    if (chaos.get("shard_restarts", 0) or 0) <= 0:
        failures.append(
            "BENCH_chaos.json: shard_restarts = 0 — the armed panic never "
            "killed a worker, so the run proved nothing"
        )
    if (chaos.get("requests_retried", 0) or 0) <= 0:
        failures.append(
            "BENCH_chaos.json: requests_retried = 0 — no recovered work "
            "was redelivered"
        )


def gate_elastic(doc):
    """Copy-on-calibrate must actually pay: the Arc-shared layer dominates
    one replica's private state, and the scale event really scaled."""
    shared = doc.get("bytes_shared", 0) or 0
    per_replica = doc.get("bytes_private_per_replica", 0) or 0
    if shared <= 0 or per_replica <= 0:
        failures.append(
            f"BENCH_elastic.json: footprint gauges missing "
            f"(bytes_shared={shared!r}, bytes_private_per_replica="
            f"{per_replica!r})"
        )
    elif shared <= per_replica:
        failures.append(
            f"BENCH_elastic.json: shared layer ({shared} B) does not "
            f"dominate per-replica private state ({per_replica} B) — "
            f"replicas are deep-copying what should be Arc-shared"
        )
    else:
        print(
            f"BENCH_elastic.json: footprint split holds "
            f"({shared} B shared vs {per_replica} B/replica private)"
        )
    event = doc.get("scale_event")
    if not isinstance(event, dict):
        failures.append("BENCH_elastic.json: no scale_event point recorded")
        return
    if (event.get("scale_up", 0) or 0) <= 0:
        failures.append(
            "BENCH_elastic.json: scale_event.scale_up = 0 — the burst "
            "never engaged the autoscaler"
        )
    else:
        print(
            f"BENCH_elastic.json: autoscaler engaged "
            f"(scale_up={event.get('scale_up'):.0f}, "
            f"peak replicas={event.get('peak_replicas', 0):.0f})"
        )
    for key in ("elastic_req_per_s", "pinned_req_per_s"):
        v = event.get(key, 0) or 0
        if not isinstance(v, (int, float)) or v <= 0:
            failures.append(
                f"BENCH_elastic.json: scale_event.{key} = {v!r} — the "
                f"burst never completed"
            )


def main(argv):
    selected = argv[1:] or list(GATES)
    unknown = [p for p in selected if p not in GATES]
    if unknown:
        print(f"unknown gate files: {unknown}; known: {list(GATES)}", file=sys.stderr)
        return 1

    for path in selected:
        fresh = gate_headline(path, GATES[path])
        if fresh is None or is_placeholder(fresh):
            continue
        if path == "BENCH_cim_mvm.json":
            gate_simd_kernel(fresh)
        elif path == "BENCH_edge.json":
            gate_edge_overload(fresh)
        elif path == "BENCH_chaos.json":
            gate_chaos_conservation(fresh)
        elif path == "BENCH_elastic.json":
            gate_elastic(fresh)

    if failures:
        print("\nBENCH GATE FAILURES:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nbench gate: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
