#!/usr/bin/env python3
"""CI regression gate for the repo-root BENCH_*.json perf artifacts.

Run from the repo root after the bench-smoke suite has regenerated the
reports (tests/mvm_props.rs, tests/grng_props.rs, tests/backend_smoke.rs
write smoke-scale seeds; benches/* write calibrated reports):

    python3 scripts/bench_gate.py

Rules:

- BENCH_cim_mvm.json must report a nonzero `speedup_single_thread`;
  BENCH_grng_fill.json must report a nonzero `speedup_block_vs_legacy`.
  A 0.0 (or missing) headline means the bench never actually ran — the
  placeholder state this gate exists to forbid.
- Each fresh headline is compared against the checked-in baseline
  (`git show HEAD:<file>`): a drop below REGRESSION_FRACTION of the
  baseline fails. Placeholder baselines (0.0, or a "smoke"-free source
  missing) only get the nonzero check, so the very first real numbers
  can land.
- When the fresh MVM report was produced with a vector `simd_level`
  (not "scalar"), the kernel-level `speedup_lane_dot_simd_vs_scalar`
  must be at least MIN_SIMD_KERNEL_SPEEDUP — the ISSUE 6 acceptance bar
  for the vectorized lane_dot on the 64-row geometry. End-to-end MVM
  numbers are dominated by ADC/ziggurat scalar work, so the bar sits on
  the kernel, where the vector arm actually runs.

Exit code 0 = all gates pass; 1 = any gate fails (fails the CI job).
"""

import json
import subprocess
import sys

REGRESSION_FRACTION = 0.8  # fresh must be >= 80% of a real baseline
MIN_SIMD_KERNEL_SPEEDUP = 1.5

GATES = [
    # (file, headline field that must be nonzero and non-regressing)
    ("BENCH_cim_mvm.json", "speedup_single_thread"),
    ("BENCH_grng_fill.json", "speedup_block_vs_legacy"),
]

failures = []


def load_fresh(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        failures.append(f"{path}: unreadable ({e})")
        return None


def load_baseline(path):
    """The checked-in report at HEAD, or None if absent/unreadable."""
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD:{path}"],
            capture_output=True,
            text=True,
            check=True,
        ).stdout
        return json.loads(out)
    except (subprocess.CalledProcessError, ValueError):
        return None


def is_placeholder(doc):
    """A report that never came from a real measurement run."""
    if doc is None:
        return True
    src = doc.get("source", "")
    return "placeholder" in src or not doc.get("cases")


def main():
    for path, field in GATES:
        fresh = load_fresh(path)
        if fresh is None:
            continue
        value = fresh.get(field, 0.0)
        if not isinstance(value, (int, float)) or value <= 0.0:
            failures.append(
                f"{path}: {field} = {value!r} — bench did not produce a real "
                f"number (placeholder not regenerated?)"
            )
            continue
        print(f"{path}: {field} = {value:.3f}")

        baseline = load_baseline(path)
        if is_placeholder(baseline):
            print(f"{path}: baseline is a placeholder — nonzero check only")
        else:
            base = baseline.get(field, 0.0)
            if isinstance(base, (int, float)) and base > 0.0:
                floor = base * REGRESSION_FRACTION
                if value < floor:
                    failures.append(
                        f"{path}: {field} regressed: {value:.3f} < "
                        f"{floor:.3f} ({REGRESSION_FRACTION:.0%} of baseline "
                        f"{base:.3f})"
                    )
                else:
                    print(
                        f"{path}: within {REGRESSION_FRACTION:.0%} of "
                        f"baseline {base:.3f}"
                    )

    # SIMD kernel bar: only when the fresh report ran on a vector arm.
    mvm = load_fresh("BENCH_cim_mvm.json")
    if mvm is not None:
        level = mvm.get("simd_level", "scalar")
        if level != "scalar":
            kernel = mvm.get("speedup_lane_dot_simd_vs_scalar", 0.0)
            if not isinstance(kernel, (int, float)) or kernel < MIN_SIMD_KERNEL_SPEEDUP:
                failures.append(
                    f"BENCH_cim_mvm.json: simd_level={level} but "
                    f"speedup_lane_dot_simd_vs_scalar = {kernel!r} < "
                    f"{MIN_SIMD_KERNEL_SPEEDUP} — vectorized lane_dot is not "
                    f"pulling its weight"
                )
            else:
                print(
                    f"BENCH_cim_mvm.json: lane_dot {level} speedup "
                    f"{kernel:.2f}x >= {MIN_SIMD_KERNEL_SPEEDUP}x"
                )
        else:
            print("BENCH_cim_mvm.json: scalar host — SIMD kernel bar skipped")

    if failures:
        print("\nBENCH GATE FAILURES:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nbench gate: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
